"""Algorithm 3 datagen and the L1 oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import problems as P


@pytest.fixture(scope="module")
def prob():
    return P.generate_problem(n=6, d=40, noise_scale=1.0, seed=3)


def test_matrices_symmetric(prob):
    A = np.asarray(prob.A)
    np.testing.assert_allclose(A, np.swapaxes(A, 1, 2), atol=1e-6)


def test_mean_matrix_min_eig_is_mu():
    prob = P.generate_problem(n=5, d=30, noise_scale=0.5, seed=1, mu=1e-6)
    Abar = np.asarray(prob.A).mean(0)
    lam = np.linalg.eigvalsh(Abar).min()
    assert lam == pytest.approx(1e-6, abs=1e-4)


def test_fstar_zero_at_origin(prob):
    assert float(prob.f(jnp.zeros(prob.d))) == 0.0


def test_subgradient_is_valid(prob):
    """Convexity: f(y) >= f(x) + <g, y - x> for the analytic subgradient."""
    key = jax.random.PRNGKey(0)
    for i in range(5):
        kx, ky, key = jax.random.split(key, 3)
        x = jax.random.normal(kx, (prob.d,))
        y = jax.random.normal(ky, (prob.d,))
        g = prob.subgrad(x)
        lhs = float(prob.f(y))
        rhs = float(prob.f(x) + g @ (y - x))
        assert lhs >= rhs - 1e-4


def test_subgrad_matches_autodiff_at_smooth_points(prob):
    """Where A_i x has no zero coords, |.|_1 is differentiable."""
    x = jnp.ones((prob.d,)) * 0.7  # generic point
    g_analytic = prob.subgrad(x)
    g_auto = jax.grad(lambda z: prob.f(z))(x)
    np.testing.assert_allclose(np.asarray(g_analytic), np.asarray(g_auto), rtol=1e-5, atol=1e-6)


def test_lipschitz_bounds_subgradients(prob):
    """||df_i(x)|| <= L_{0,i} sqrt(d) (the paper's App.A bound)."""
    key = jax.random.PRNGKey(7)
    xs = jax.random.normal(key, (prob.n, prob.d))
    gs = prob.subgrad_all(xs)
    norms = jnp.linalg.norm(gs, axis=-1)
    bound = prob.L0i * np.sqrt(prob.d)
    assert (np.asarray(norms) <= np.asarray(bound) + 1e-4).all()


def test_sigma_A_monotone_in_noise():
    sigmas = [
        P.generate_problem(n=8, d=30, noise_scale=s, seed=0).sigma_A for s in (0.1, 1.0, 10.0)
    ]
    assert sigmas[0] < sigmas[1] < sigmas[2]


def test_paper_sign_convention():
    out = P.paper_sign(jnp.array([-1.0, 0.0, 2.0]))
    np.testing.assert_allclose(np.asarray(out), [-1.0, 1.0, 1.0])
