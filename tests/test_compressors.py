"""Definition 2/3 properties of every compressor (hypothesis + statistics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compressors as C

DIMS = st.integers(min_value=8, max_value=200)
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _x(d, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), (d,))


# ---------------------------------------------------------------------------
# contraction (Definition 3): E||C(x)-x||^2 <= (1-alpha)||x||^2
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(d=DIMS, seed=SEEDS, k=st.integers(1, 8))
def test_topk_contractive(d, seed, k):
    x = _x(d, seed)
    comp = C.TopK(k=k)
    err = jnp.sum((comp(None, x) - x) ** 2)
    alpha = comp.alpha(d)
    assert float(err) <= (1 - alpha) * float(jnp.sum(x**2)) + 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=SEEDS, kb=st.integers(1, 16), block=st.sampled_from([16, 32, 64]))
def test_block_topk_contractive(seed, kb, block):
    d = 4 * block
    x = _x(d, seed)
    comp = C.BlockTopK(k_per_block=kb, block=block)
    err = jnp.sum((comp(None, x) - x) ** 2)
    assert float(err) <= (1 - comp.alpha(d)) * float(jnp.sum(x**2)) + 1e-5


def test_topk_keeps_largest():
    x = jnp.array([0.1, -5.0, 2.0, 0.01, -3.0])
    out = C.TopK(k=2)(None, x)
    np.testing.assert_allclose(out, [0.0, -5.0, 0.0, 0.0, -3.0])


# ---------------------------------------------------------------------------
# unbiasedness (Definition 2): E[Q(x)] = x (statistical), omega bound
# ---------------------------------------------------------------------------


def _check_unbiased(comp, d, n_samples=4000, tol=0.12):
    x = _x(d, 0)
    keys = jax.random.split(jax.random.PRNGKey(1), n_samples)
    qs = jax.vmap(lambda k: comp(k, x))(keys)
    mean_err = jnp.linalg.norm(jnp.mean(qs, 0) - x) / jnp.linalg.norm(x)
    assert float(mean_err) < tol, float(mean_err)
    # omega bound: E||Q(x)-x||^2 <= omega ||x||^2 (allow 10% stat slack)
    var = jnp.mean(jnp.sum((qs - x) ** 2, axis=-1))
    bound = comp.omega(d) * jnp.sum(x**2)
    assert float(var) <= 1.1 * float(bound) + 1e-6, (float(var), float(bound))


def test_randk_unbiased():
    _check_unbiased(C.RandK(k=8), 32)


def test_bernk_unbiased():
    _check_unbiased(C.BernK(k=8), 32)


def test_natural_unbiased():
    _check_unbiased(C.NaturalCompression(), 32, tol=0.05)


def test_rotk_unbiased():
    _check_unbiased(C.RotK(n=4, worker=2), 32)


def test_permk_unbiased():
    _check_unbiased(C.PermK(n=4, worker=1), 32)


# ---------------------------------------------------------------------------
# correlated-family identities
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=SEEDS, n=st.sampled_from([2, 4, 8]))
def test_permk_exact_mean(seed, n):
    """(1/n) sum_i Q_i(x) = x deterministically (Definition 5 key property)."""
    d = 8 * n
    x = _x(d, seed)
    key = jax.random.PRNGKey(seed)
    total = sum(C.PermK(n=n, worker=i)(key, x) for i in range(n))
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(x), rtol=2e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=SEEDS, n=st.sampled_from([2, 4, 8]), d=st.sampled_from([16, 64, 96]))
def test_rotk_exact_mean(seed, n, d):
    """RotK inherits PermK's exact partition identity (DESIGN.md §2)."""
    x = _x(d, seed)
    key = jax.random.PRNGKey(seed)
    total = sum(C.RotK(n=n, worker=i)(key, x) for i in range(n))
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(x), rtol=2e-5, atol=1e-6)


def test_permk_disjoint_supports():
    n, d = 4, 32
    x = jnp.ones((d,))
    key = jax.random.PRNGKey(3)
    masks = [np.asarray(C.PermK(n=n, worker=i)(key, x) != 0) for i in range(n)]
    overlap = np.zeros(d, dtype=int)
    for m in masks:
        overlap += m
    assert (overlap == 1).all()  # exact partition


# ---------------------------------------------------------------------------
# expected density (Definition 4) and scaled-unbiased lemma
# ---------------------------------------------------------------------------


def test_expected_density():
    assert C.TopK(k=5).expected_density(100) == 5
    assert C.RandK(k=7).expected_density(100) == 7
    assert C.PermK(n=10).expected_density(100) == 10
    assert C.BlockTopK(k_per_block=4, block=16).expected_density(64) == 16
    assert C.Identity().expected_density(9) == 9


def test_scaled_unbiased_is_contractive():
    d = 64
    inner = C.RandK(k=8)
    comp = C.ScaledUnbiased(inner=inner)
    x = _x(d, 5)
    keys = jax.random.split(jax.random.PRNGKey(2), 3000)
    errs = jax.vmap(lambda k: jnp.sum((comp(k, x) - x) ** 2))(keys)
    alpha = comp.alpha(d)
    assert float(jnp.mean(errs)) <= 1.1 * (1 - alpha) * float(jnp.sum(x**2))


def test_make_compressor_registry():
    assert isinstance(C.make_compressor("topk:4", d=100), C.TopK)
    assert isinstance(C.make_compressor("randk:4", d=100), C.RandK)
    assert isinstance(C.make_compressor("permk", d=100, n=4, worker=1), C.PermK)
    assert isinstance(C.make_compressor("natural", d=100), C.NaturalCompression)
    assert isinstance(C.make_compressor("identity", d=100), C.Identity)
    with pytest.raises(ValueError):
        C.make_compressor("bogus", d=10)


def test_tree_compress_roundtrip_structure():
    tree = {"a": jnp.ones((3, 4)), "b": {"c": jnp.zeros((5,))}}
    out = C.tree_compress(C.TopK(k=2), None, tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    assert out["a"].shape == (3, 4)
