"""benchmarks.scenario_matrix: cells, artifacts, and the stepsize_grid shim."""
import json
import warnings

import numpy as np
import pytest

from benchmarks import run as bench_run
from benchmarks import scenario_matrix, stepsize_grid
from repro import obs
from repro.core import problems

TINY = dict(population=256, cohort=8, d=24, T=40, seed=0)


def test_mini_matrix_cells_emit_valid_artifacts(tmp_path):
    cells = [("marina_p", "constant", "uniform"),
             ("ef21p", "polyak", "two_tier_diurnal")]
    rows = scenario_matrix.bench(out_dir=str(tmp_path), cells=cells, **TINY)
    names = [r[0] for r in rows]
    assert "scenario/marina_p-constant-uniform/rounds_to_target" in names
    assert "scenario/ef21p-polyak-two_tier_diurnal/s2w_bits" in names
    for alg, scheme, mix in cells:
        cid = scenario_matrix.cell_id(alg, scheme, mix)
        path = tmp_path / f"BENCH_scenario_{cid}.json"
        assert path.exists()
        doc = json.load(open(path))
        assert obs.validate(doc) == []
        m = doc["metrics"]
        # ISSUE acceptance: rounds-to-target and downlink-bits fields per cell
        assert "rounds_to_target" in m and np.isfinite(m["rounds_to_target"]["value"])
        assert m["downlink_bits_analytic"]["value"] > 0
        assert m["downlink_bits_measured"]["value"] > 0
        assert 0 < m["goodput"]["value"] <= 1.0
        assert m["participants_mean"]["value"] <= TINY["cohort"]


def test_matrix_cells_deterministic(tmp_path):
    cells = [("marina_p", "polyak", "uniform")]
    r1 = scenario_matrix.bench(out_dir=str(tmp_path / "a"), cells=cells, **TINY)
    r2 = scenario_matrix.bench(out_dir=str(tmp_path / "b"), cells=cells, **TINY)
    # same seed -> identical derived values (timing column differs)
    assert [(n, d) for n, _, d in r1] == [(n, d) for n, _, d in r2]


def test_default_matrix_covers_issue_floor():
    # >= 8 cells: 2 algorithms x 2 schemes x 2 mixes
    assert len(scenario_matrix.DEFAULT_CELLS) >= 8
    algs = {c[0] for c in scenario_matrix.DEFAULT_CELLS}
    schemes = {c[1] for c in scenario_matrix.DEFAULT_CELLS}
    mixes = {c[2] for c in scenario_matrix.DEFAULT_CELLS}
    assert algs == {"marina_p", "ef21p"} and len(schemes) >= 2 and len(mixes) >= 2
    for _, _, mix in scenario_matrix.FULL_CELLS:
        assert mix in scenario_matrix.MIX_SAMPLER


def test_stepsize_grid_shim_warns_and_keeps_row_names():
    prob = problems.generate_problem(n=4, d=16, noise_scale=1.0, seed=0)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        rows = stepsize_grid.bench(prob=prob, T=4, factors=[1.0], methods=("perm",))
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert [r[0] for r in rows] == ["stepsize_grid/polyak/perm/best_factor",
                                    "stepsize_grid/polyak/perm/final_subopt"]
    # the folded-in API is reachable from scenario_matrix directly, no warning
    assert stepsize_grid.tune is scenario_matrix.tune


def test_run_py_registers_scenario_suite_with_gates():
    gates = bench_run.GATES["scenario"]
    patterns = {g["pattern"] for g in gates}
    assert "scenario/*/rounds_to_target" in patterns
    assert "scenario/*/goodput" in patterns
    # legacy suite name still registered (deprecation shim target)
    assert "stepsize_grid" in bench_run.GATES
