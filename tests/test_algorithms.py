"""Convergence / descent behaviour of EF21-P, MARINA-P and SM (Thms 1 & 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compressors as C
from repro.core import ef21p, marina_p, problems, stepsizes, subgradient


@pytest.fixture(scope="module")
def prob():
    return problems.generate_problem(n=8, d=64, noise_scale=1.0, seed=0)


def test_ef21p_theory_rate_constant(prob):
    """f(wbar_T) - f* <= sqrt(B* L0^2 V0 / T)  (eq. 12), empirical check.

    The bound requires a TRUE Lipschitz constant: ||df_i|| <= ||A_i||_2 sqrt(d)
    (paper App. A). The paper's practical estimate L0 ~ mean ||A_i||_2 is not
    a bound, so we verify against the rigorous constant.
    """
    T = 300
    alpha = 8 / prob.d
    L_true = prob.L0 * prob.d**0.5
    gamma = stepsizes.ef21p_optimal_constant(prob.R0_sq, L_true, alpha, T)
    h = ef21p.run(prob, C.TopK(k=8), stepsizes.Constant(gamma), T=T)
    bound = (stepsizes.ef21p_B_star(alpha) * L_true**2 * prob.R0_sq) ** 0.5 / T**0.5
    # the bound controls the ergodic average of E[f(w^t)] (eq. 77)
    assert np.mean(h["f_w"]) <= bound * 1.05


def test_ef21p_polyak_converges(prob):
    alpha = 8 / prob.d
    ss = stepsizes.EF21PPolyak(alpha=alpha, f_star=prob.f_star)
    h = ef21p.run(prob, C.TopK(k=8), ss, T=400)
    assert h["f_x"][-1] < 0.2 * h["f_x"][0]


def test_ef21p_lyapunov_decreases_polyak(prob):
    """Polyak stepsize minimizes the descent-lemma RHS => V^t decreases in
    expectation; check the trend on a single trajectory."""
    alpha = 8 / prob.d
    ss = stepsizes.EF21PPolyak(alpha=alpha, f_star=prob.f_star)
    step = jax.jit(ef21p.make_step(prob, C.TopK(k=8), ss))
    state = ef21p.init(prob.x0)
    xstar = jnp.zeros(prob.d)
    vs = [float(ef21p.lyapunov(state, xstar, alpha))]
    key = jax.random.PRNGKey(0)
    for i in range(50):
        key, sub = jax.random.split(key)
        state, _ = step(state, sub)
        vs.append(float(ef21p.lyapunov(state, xstar, alpha)))
    assert vs[-1] < vs[0]
    # mostly monotone (TopK is deterministic => strictly non-increasing here)
    dec = sum(1 for a, b in zip(vs, vs[1:]) if b <= a + 1e-6)
    assert dec >= 45


@pytest.mark.parametrize("mode", ["same", "ind", "perm"])
def test_marina_p_converges_all_modes(prob, mode):
    k = prob.d // prob.n
    p = k / prob.d
    omega = prob.n - 1 if mode == "perm" else prob.d / k - 1
    ss = stepsizes.MarinaPPolyak(omega=omega, p=p, f_star=prob.f_star)
    h = marina_p.run(prob, mode=mode, k=k, p=p, stepsize=ss, T=400, seed=1)
    assert h["f_x"][-1] < 0.25 * h["f_x"][0], (mode, h["f_x"][-1], h["f_x"][0])


def test_marina_p_perm_beats_same_on_heterogeneous():
    """The paper's headline: correlated compressors win (Fig. 1/7)."""
    prob = problems.generate_problem(n=10, d=100, noise_scale=1.0, seed=2)
    k = prob.d // prob.n
    p = k / prob.d
    T = 500

    def run(mode, omega):
        ss = stepsizes.MarinaPPolyak(omega=omega, p=p, f_star=0.0)
        return marina_p.run(prob, mode=mode, k=k, p=p, stepsize=ss, T=T, seed=3)

    h_same = run("same", prob.d / k - 1)
    h_perm = run("perm", prob.n - 1)
    assert h_perm["f_x"][-1] < h_same["f_x"][-1]


def test_marina_p_full_sync_matches_sm(prob):
    """p=1 (always send x^{t+1}) reduces MARINA-P to plain SM."""
    ss = stepsizes.Constant(0.01)
    h_m = marina_p.run(prob, mode="same", k=8, p=1.0, stepsize=ss, T=50, seed=0)
    h_s = subgradient.run(prob, ss, T=50, seed=0)
    np.testing.assert_allclose(h_m["f_x"], h_s["f_x"], rtol=1e-5)


def test_ef21p_identity_matches_sm(prob):
    """alpha=1 (identity compressor): w^t = x^t, EF21-P == SM."""
    ss = stepsizes.Constant(0.01)
    h_e = ef21p.run(prob, C.Identity(), ss, T=50, seed=0)
    h_s = subgradient.run(prob, ss, T=50, seed=0)
    np.testing.assert_allclose(h_e["f_x"], h_s["f_x"], rtol=1e-5)


def test_bit_budget_termination(prob):
    h = ef21p.run(prob, C.TopK(k=8), stepsizes.Constant(0.01), bit_budget=5e4)
    assert h["ledger"].s2w_bits >= 5e4
    assert h["ledger"].rounds < 200


def test_marina_drift_bounded(prob):
    k = prob.d // prob.n
    p = k / prob.d
    ss = stepsizes.Constant(0.005)
    h = marina_p.run(prob, mode="perm", k=k, p=p, stepsize=ss, T=200, seed=4)
    assert h["drift"][-1] < 10 * prob.R0_sq
