"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d,block,k", [(512, 128, 8), (1024, 256, 16), (2048, 512, 1), (1000, 128, 4)])
def test_block_topk_sweep(d, block, k, dtype):
    x = jax.random.normal(jax.random.PRNGKey(d + k), (d,)).astype(dtype)
    got = ops.block_topk(x, k_per_block=k, block=block)
    # oracle on the padded vector (same semantics as the kernel wrapper)
    pad = (-d) % block
    want = ref.block_topk_ref(jnp.pad(x, (0, pad)), k_per_block=k, block=block)[:d]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d,keep", [(512, 0.1), (1024, 0.5), (777, 0.03)])
def test_bernk_sweep(d, keep, dtype):
    x = jax.random.normal(jax.random.PRNGKey(d), (d,)).astype(dtype)
    got = ops.bernk(x, keep_prob=keep, seed=11, worker=2, block=256)
    want = ref.bernk_ref(x, keep_prob=keep, seed=11, worker=2)
    # identical sparsity pattern; values allclose (1-ulp division assoc.)
    np.testing.assert_array_equal(np.asarray(got != 0), np.asarray(want != 0))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=1e-5, atol=1e-6
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n=st.sampled_from([4, 16]), worker=st.integers(0, 3))
def test_rotk_apply_hypothesis(seed, n, worker):
    d = 1024
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (d,))
    delta = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    rot = jnp.int32(seed % n)
    got = ops.rotk_apply(w, delta, rot, n=n, worker=worker, block=256)
    want = ref.rotk_apply_ref(w, delta, rot, n=n, worker=worker)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_rotk_partition_identity_via_kernel():
    """sum over workers of kernel-applied updates == w + delta (exact mean)."""
    d, n = 512, 8
    w = jnp.zeros((d,))
    delta = jax.random.normal(jax.random.PRNGKey(0), (d,))
    rot = jnp.int32(3)
    acc = sum(np.asarray(ops.rotk_apply(w, delta, rot, n=n, worker=i, block=128)) for i in range(n))
    np.testing.assert_allclose(acc / n, np.asarray(delta) / 1, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("m,d", [(128, 128), (256, 384), (1000, 1000), (100, 257)])
def test_l1_subgrad_sweep(m, d):
    key = jax.random.PRNGKey(m + d)
    A = jax.random.normal(key, (m, d))
    x = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    got = ops.l1_subgrad(A, x)
    want = ref.l1_subgrad_ref(A, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_l1_subgrad_matches_problem_oracle():
    """Kernel == the core library's analytic subgradient on the paper workload."""
    from repro.core import problems

    prob = problems.generate_problem(n=2, d=100, noise_scale=1.0, seed=0)
    x = jax.random.normal(jax.random.PRNGKey(5), (100,))
    got = ops.l1_subgrad(prob.A[0], x)
    want = prob.subgrad_i(0, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_block_topk_contraction_property():
    """Kernel output satisfies Definition 3 with alpha = k/b."""
    d, block, k = 2048, 256, 32
    x = jax.random.normal(jax.random.PRNGKey(9), (d,))
    out = ops.block_topk(x, k_per_block=k, block=block)
    err = float(jnp.sum((out - x) ** 2))
    assert err <= (1 - k / block) * float(jnp.sum(x**2)) + 1e-5
