"""Decode path == prefill forward (teacher forcing), per family.

This validates the KV/SSM caches, ring buffers, RoPE positions, the MLA
absorbed-matmul decode, and the recurrent decode steps against the chunked
training forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm

ARCHS = [
    "gemma-2b",          # MQA + GeGLU
    "starcoder2-7b",     # GQA
    "deepseek-v2-236b",  # MLA absorbed decode + MoE
    "zamba2-1.2b",       # mamba2 recurrence + shared attn
    "rwkv6-1.6b",        # rwkv6 recurrence
    "gemma3-1b",         # sliding-window ring buffer
    "musicgen-large",    # codebooks
    "llama4-maverick-400b-a17b",  # MoE top-1
]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = lm.lm_init(cfg, key)
    B, S = 2, 32
    if cfg.num_codebooks:
        tokens = jax.random.randint(key, (B, cfg.num_codebooks, S), 0, cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    # full forward (no patches variant here; pixtral covered in smoke tests)
    full_logits = lm.forward(cfg, params, batch, chunk=8, remat=False)
    # decode token-by-token
    caches = lm.cache_init(cfg, B, S)
    outs = []
    step = jax.jit(lambda p, c, t, i: lm.decode_step(cfg, p, c, t, i))
    for i in range(S):
        tok = tokens[..., i : i + 1]
        logits, caches = step(params, caches, tok, jnp.int32(i))
        outs.append(logits)
    dec = jnp.stack(outs, axis=-2)  # [B, (K,) S, V]
    a = np.asarray(full_logits, dtype=np.float32)
    b = np.asarray(dec, dtype=np.float32)
    # bf16 activations + different contraction orders: compare top-1 + values
    np.testing.assert_allclose(a, b, rtol=0.12, atol=0.12)
    top_full = a.argmax(-1)
    top_dec = b.argmax(-1)
    agree = (top_full == top_dec).mean()
    assert agree > 0.9, (arch, agree)


def test_sliding_window_ring_buffer_consistency():
    """Decode beyond the window length must keep matching the windowed forward."""
    cfg = configs.get_smoke("gemma3-1b")  # window 16
    key = jax.random.PRNGKey(1)
    params = lm.lm_init(cfg, key)
    B, S = 1, 48  # 3x the window
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits = lm.forward(cfg, params, {"tokens": tokens}, chunk=8, remat=False)
    caches = lm.cache_init(cfg, B, S)
    step = jax.jit(lambda p, c, t, i: lm.decode_step(cfg, p, c, t, i))
    outs = []
    for i in range(S):
        logits, caches = step(params, caches, tokens[:, i : i + 1], jnp.int32(i))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    a = np.asarray(full_logits, np.float32)[:, -8:]
    b = np.asarray(dec, np.float32)[:, -8:]
    np.testing.assert_allclose(a, b, rtol=0.12, atol=0.12)


def test_long_context_window_override():
    """The SWA serving variant: window_override shrinks dense-arch caches."""
    cfg = configs.get_smoke("starcoder2-7b")
    caches_full = lm.cache_init(cfg, 1, 1024)
    caches_swa = lm.cache_init(cfg, 1, 1024, window_override=64)
    size = lambda c: sum(x.size for x in jax.tree.leaves(c))
    assert size(caches_swa) * 8 <= size(caches_full)
