"""Theory constants and stepsize formulas of Theorems 1 & 2."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stepsizes as S


def test_B_star_limits():
    # alpha=1 (no compression): B* = 1 — matches uncompressed SM constant
    assert S.ef21p_B_star(1.0) == 1.0
    # B* <= 4/alpha - 1 (paper eq. 100)
    for a in [0.01, 0.1, 0.5, 0.9]:
        assert S.ef21p_B_star(a) <= 4.0 / a - 1.0 + 1e-9
    # decreasing in alpha
    vals = [S.ef21p_B_star(a) for a in [0.1, 0.3, 0.5, 0.9]]
    assert all(x > y for x, y in zip(vals, vals[1:]))


def test_lambda_star_ef21p():
    a = 0.36
    r = math.sqrt(1 - a)
    assert S.ef21p_lambda_star(a) == pytest.approx(r / (1 - r))


def test_marina_B_star():
    # p=1 (always full sync): Btil* = Lbar^2 — matches uncompressed
    assert S.marina_p_B_star(2.0, 3.0, omega=5.0, p=1.0) == pytest.approx(4.0)
    got = S.marina_p_B_star(2.0, 3.0, omega=9.0, p=0.1)
    expect = 4.0 + 2 * 2 * 3 * math.sqrt(0.9 * 9.0 / 0.1)
    assert got == pytest.approx(expect)


def test_optimal_constant_formulas():
    V0, L0, a, T = 7.0, 2.0, 0.25, 100
    g = S.ef21p_optimal_constant(V0, L0, a, T)
    assert g == pytest.approx(math.sqrt(V0 / (S.ef21p_B_star(a) * L0**2)) / math.sqrt(T))
    g0 = S.ef21p_optimal_decreasing_gamma0(V0, L0, a, T)
    assert g0 == pytest.approx(math.sqrt(V0 / (2 * S.ef21p_B_star(a) * L0**2 * math.log(T + 1))))


def test_decreasing_schedule():
    sch = S.Decreasing(gamma0=2.0)
    assert float(sch(0)) == pytest.approx(2.0)
    assert float(sch(3)) == pytest.approx(1.0)


def test_ef21p_polyak_matches_eq13():
    a = 0.5
    sch = S.EF21PPolyak(alpha=a, f_star=1.0)
    aux = {"f_w": jnp.asarray(3.0), "g_norm_sq": jnp.asarray(4.0)}
    expect = (3.0 - 1.0) / (S.ef21p_B_star(a) * 4.0)
    assert float(sch(0, aux)) == pytest.approx(expect)


def test_marina_polyak_matches_eq23():
    omega, p = 9.0, 0.1
    sch = S.MarinaPPolyak(omega=omega, p=p, f_star=0.0)
    aux = {
        "f_w": jnp.asarray(2.0),
        "g_norm_sq": jnp.asarray(4.0),  # ||g|| = 2
        "g_sq_mean": jnp.asarray(9.0),  # sqrt = 3
    }
    c = math.sqrt((1 - p) * omega / p)
    denom = 4.0 + 2 * 2.0 * 3.0 * c
    assert float(sch(0, aux)) == pytest.approx(2.0 / denom, rel=1e-5)


def test_polyak_never_negative():
    sch = S.EF21PPolyak(alpha=0.3, f_star=10.0)
    aux = {"f_w": jnp.asarray(1.0), "g_norm_sq": jnp.asarray(4.0)}
    assert float(sch(0, aux)) == 0.0


def test_registry():
    assert isinstance(S.make_stepsize("constant:0.5"), S.Constant)
    assert isinstance(S.make_stepsize("decreasing:0.1"), S.Decreasing)
    assert isinstance(S.make_stepsize("polyak_ef21p", alpha=0.2), S.EF21PPolyak)
    assert isinstance(S.make_stepsize("polyak_marina_p", omega=3.0, p=0.25), S.MarinaPPolyak)
    with pytest.raises(ValueError):
        S.make_stepsize("bogus")
