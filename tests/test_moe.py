"""MoE dispatch correctness: sort-based capacity routing vs dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as MOE
from repro.models.config import ModelConfig, MoEConfig


def _cfg(E=4, K=1, cap=8.0, shared=0):
    return ModelConfig(
        arch_id="t", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
        block_pattern=("moe",),
        moe=MoEConfig(num_experts=E, top_k=K, d_ff_expert=48, num_shared=shared,
                      d_ff_shared=48, capacity_factor=cap),
    )


def _dense_reference(cfg, params, x):
    """Every token through its top-k experts, no capacity limit."""
    m = cfg.moe
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    logits = (xf @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, m.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for e in range(m.num_experts):
        h = xf @ params["w_in"][e]
        g = xf @ params["w_gate"][e]
        he = jax.nn.silu(g) * h
        ye = he @ params["w_out"][e]
        w_e = ((ids == e) * gates).sum(-1)[:, None]
        y = y + w_e * ye
    return y.reshape(B, S, D)


@pytest.mark.parametrize("K", [1, 2])
def test_moe_matches_dense_reference_ample_capacity(K):
    cfg = _cfg(E=4, K=K, cap=8.0)  # capacity >> tokens: nothing dropped
    key = jax.random.PRNGKey(0)
    params = jax.tree.map(lambda t: t.astype(jnp.float32), MOE.moe_init(cfg, key))
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model), jnp.float32)
    got, aux = MOE.moe_apply(cfg, params, x, return_aux=True)
    want = _dense_reference(cfg, params, x)
    assert float(aux["dropped_frac"]) == 0.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_overflow():
    cfg = _cfg(E=4, K=1, cap=0.25)  # tiny capacity: most tokens dropped
    key = jax.random.PRNGKey(2)
    params = MOE.moe_init(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 16, cfg.d_model), jnp.float32)
    _, aux = MOE.moe_apply(cfg, params, x, return_aux=True)
    assert float(aux["dropped_frac"]) > 0.3


def test_moe_shared_expert_always_on():
    cfg = _cfg(E=4, K=1, cap=8.0, shared=1)
    key = jax.random.PRNGKey(4)
    params = MOE.moe_init(cfg, key)
    assert "shared" in params
    x = jax.random.normal(jax.random.fold_in(key, 5), (1, 4, cfg.d_model), jnp.float32)
    y = MOE.moe_apply(cfg, params, x)
    # zero-out router: shared path must still contribute
    params0 = dict(params, router=jnp.zeros_like(params["router"]))
    y0 = MOE.moe_apply(cfg, params0, x)
    assert float(jnp.max(jnp.abs(y0))) > 0


def test_group_by_expert_slots_unique():
    ids = jnp.array([2, 0, 1, 0, 2, 2, 1, 0], jnp.int32)
    slot, keep = MOE._group_by_expert(ids, num_experts=3, capacity=2)
    kept_slots = np.asarray(slot)[np.asarray(keep)]
    assert len(set(kept_slots.tolist())) == len(kept_slots)  # no collisions
    # per-expert kept count <= capacity
    for e in range(3):
        assert ((kept_slots // 2) == e).sum() <= 2


def test_load_balance_loss_uniform_router():
    cfg = _cfg(E=4, K=1, cap=8.0)
    key = jax.random.PRNGKey(6)
    params = MOE.moe_init(cfg, key)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(key, (4, 32, cfg.d_model), jnp.float32)
    _, aux = MOE.moe_apply(cfg, params, x, return_aux=True)
    # uniform router => balance loss ~= 1.0 (E * sum_e (1/E)*(1/E) * E = 1)
    assert 0.8 < float(aux["load_balance_loss"]) < 1.2
