"""repro.obs: tracker backends, BENCH_*.json schema, bench_diff gating."""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.obs.bench_json import BenchJsonSink

from benchmarks import bench_diff


# -- tracker backends --------------------------------------------------------


def _drive(tracker):
    tracker.log({"loss": jnp.float32(2.5), "nested": {"a": 1, "b": {"c": 2}}}, step=0)
    tracker.log_row("suite/metric", 12.5, 0.75)
    with tracker.time_block("blk", step=1) as tb:
        tb.block(jnp.ones(()) * 2)


def test_memory_vs_jsonl_equivalence(tmp_path):
    mem = obs.MemoryTracker()
    path = os.path.join(tmp_path, "events.jsonl")
    jl = obs.JsonlTracker(path)
    _drive(obs.CompositeTracker(mem, jl))
    jl.finish()
    replayed = obs.read_jsonl(path)
    assert len(replayed) == len(mem.events) == 3
    assert obs.events_equal(mem.events, replayed)


def test_nested_dict_flattening():
    flat = obs.flatten_metrics({"a": {"b": 1, "c": {"d": 2.5}}, "e": "s", "f": 3})
    assert flat == {"a/b": 1, "a/c/d": 2.5, "e": "s", "f": 3}
    # jax/numpy scalars coerce to python scalars
    flat = obs.flatten_metrics({"x": jnp.float32(1.5), "y": jnp.int32(2)})
    assert flat == {"x": 1.5, "y": 2} and isinstance(flat["x"], float)


def test_timer_monotonic_under_jit():
    """block_until_ready-correct timers: positive durations, nondecreasing
    wall clock, and the blocked jitted work is charged to its block."""
    mem = obs.MemoryTracker()
    f = jax.jit(lambda x: (x @ x).sum())
    x = jnp.ones((128, 128))
    for i in range(3):
        with mem.time_block("mm", step=i) as tb:
            tb.block(f(x))
    timers = [e for e in mem.events if e["kind"] == "timer"]
    assert len(timers) == 3
    assert all(t["seconds"] > 0 for t in timers)
    walls = [t["wall_time"] for t in timers]
    assert walls == sorted(walls)
    assert [t["step"] for t in timers] == [0, 1, 2]


def test_csv_stdout_format(capsys):
    t = obs.CsvStdoutTracker(header=True)
    t.log_row("a/b", 12.34, "0.5GB/s")
    t.log({"ignored": 1})  # non-row events don't print
    out = capsys.readouterr().out.splitlines()
    assert out == ["name,us_per_call,derived", "a/b,12.3,0.5GB/s"]


# -- BENCH_*.json schema -----------------------------------------------------


def _make_doc(tmp_path, gates=None, rows=(("s/m", 10.0, 2.0),)):
    sink = BenchJsonSink("t1", str(tmp_path), seed=0, gates=gates or [])
    for name, us, derived in rows:
        sink.log_row(name, us, derived)
    with sink.time_block("t1/block"):
        pass
    sink.finish()
    return sink.path


def test_bench_json_schema_roundtrip(tmp_path):
    path = _make_doc(
        tmp_path,
        gates=[{"pattern": "s/*", "field": "value", "direction": "eq", "rtol": 0.1}],
    )
    doc = obs.load(path)
    assert obs.validate(doc) == []
    assert doc["schema_version"] == obs.SCHEMA_VERSION
    assert doc["suite"] == "t1"
    assert doc["metrics"]["s/m"] == {"count": 1, "us_per_call": 10.0, "value": 2.0}
    assert doc["timers"]["t1/block"]["n"] == 1
    for k in ("git_rev", "jax_version", "device_kind", "platform", "seed"):
        assert k in doc["env"]
    # round-trip: re-serialize identically
    assert json.loads(json.dumps(doc)) == doc


def test_bench_json_percentiles(tmp_path):
    sink = BenchJsonSink("pct", str(tmp_path), seed=0)
    for v in range(100):
        sink.log({"lat": float(v)})
    doc = sink.document()
    entry = doc["metrics"]["lat"]
    assert entry["count"] == 100 and entry["value"] == 99.0  # last wins
    assert entry["p50"] == pytest.approx(50.0, abs=1.0)
    assert entry["p99"] == pytest.approx(99.0, abs=1.0)


def test_bench_json_validate_catches_violations():
    assert obs.validate({}) != []
    doc = {
        "schema_version": obs.SCHEMA_VERSION, "suite": "x", "created_unix": 0.0,
        "env": {"git_rev": None, "jax_version": None, "device_kind": None,
                "platform": None, "seed": 0},
        "metrics": {"m": {"count": 0}},  # count < 1
        "timers": {}, "gates": [{"pattern": "m"}],  # incomplete gate
    }
    errors = obs.validate(doc)
    assert any("count" in e for e in errors)
    assert any("gates[0]" in e for e in errors)


# -- bench_diff regression gating --------------------------------------------


def _doc(metrics, gates):
    return {
        "schema_version": obs.SCHEMA_VERSION, "suite": "s", "created_unix": 0.0,
        "env": {"git_rev": None, "jax_version": None, "device_kind": None,
                "platform": None, "seed": 0},
        "metrics": metrics, "timers": {}, "gates": gates,
    }


GATE_LOWER = [{"pattern": "m/*", "field": "us_per_call", "direction": "lower", "rtol": 0.5}]


def test_bench_diff_within_tolerance_passes():
    base = _doc({"m/a": {"count": 1, "us_per_call": 100.0}}, GATE_LOWER)
    fresh = _doc({"m/a": {"count": 1, "us_per_call": 120.0}}, GATE_LOWER)
    failures, checked = bench_diff.diff_docs(base, fresh)
    assert failures == [] and checked == ["m/a:us_per_call"]


def test_bench_diff_tolerance_boundary():
    base = _doc({"m/a": {"count": 1, "us_per_call": 100.0}}, GATE_LOWER)
    at = _doc({"m/a": {"count": 1, "us_per_call": 150.0}}, GATE_LOWER)
    over = _doc({"m/a": {"count": 1, "us_per_call": 150.0001}}, GATE_LOWER)
    assert bench_diff.diff_docs(base, at)[0] == []  # exactly at threshold passes
    assert bench_diff.diff_docs(base, over)[0] != []


def test_bench_diff_directions():
    g_hi = [{"pattern": "m", "field": "value", "direction": "higher", "rtol": 0.2}]
    base = _doc({"m": {"count": 1, "value": 1.0}}, g_hi)
    assert bench_diff.diff_docs(base, _doc({"m": {"count": 1, "value": 0.85}}, g_hi))[0] == []
    assert bench_diff.diff_docs(base, _doc({"m": {"count": 1, "value": 0.75}}, g_hi))[0] != []
    g_eq = [{"pattern": "m", "field": "value", "direction": "eq", "rtol": 0.1}]
    base = _doc({"m": {"count": 1, "value": 2.0}}, g_eq)
    assert bench_diff.diff_docs(base, _doc({"m": {"count": 1, "value": 2.19}}, g_eq))[0] == []
    assert bench_diff.diff_docs(base, _doc({"m": {"count": 1, "value": 2.21}}, g_eq))[0] != []


def test_bench_diff_missing_metric_fails():
    base = _doc({"m/a": {"count": 1, "us_per_call": 100.0}}, GATE_LOWER)
    fresh = _doc({}, GATE_LOWER)
    failures, _ = bench_diff.diff_docs(base, fresh)
    assert failures and "missing" in failures[0]


def test_bench_diff_dirs_missing_baseline(tmp_path):
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    with open(fresh_dir / "BENCH_s.json", "w") as fh:
        json.dump(_doc({}, []), fh)
    failures, _ = bench_diff.diff_dirs(str(base_dir), str(fresh_dir))
    assert failures and "no committed baseline" in failures[0]
    failures, report = bench_diff.diff_dirs(
        str(base_dir), str(fresh_dir), ignore_missing=True
    )
    assert failures == [] and any("ignored" in r for r in report)


def test_bench_diff_missing_fresh_fails(tmp_path):
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    with open(base_dir / "BENCH_s.json", "w") as fh:
        json.dump(_doc({}, []), fh)
    failures, _ = bench_diff.diff_dirs(str(base_dir), str(fresh_dir))
    assert failures and "fresh" in failures[0]


def test_bench_diff_cli_exit_codes(tmp_path):
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    doc = _doc({"m/a": {"count": 1, "us_per_call": 100.0}}, GATE_LOWER)
    for d in (base_dir, fresh_dir):
        with open(d / "BENCH_s.json", "w") as fh:
            json.dump(doc, fh)
    assert bench_diff.main(["--baseline", str(base_dir), "--fresh", str(fresh_dir)]) == 0
    bad = _doc({"m/a": {"count": 1, "us_per_call": 1000.0}}, GATE_LOWER)
    with open(fresh_dir / "BENCH_s.json", "w") as fh:
        json.dump(bad, fh)
    assert bench_diff.main(["--baseline", str(base_dir), "--fresh", str(fresh_dir)]) == 1


# -- benchmarks.run harness --------------------------------------------------


def test_run_propagates_suite_failure(tmp_path, monkeypatch, capsys):
    """A raising suite must fail the run (no more FAILED,0,nan + exit 0)."""
    from benchmarks import run as bench_run
    from benchmarks import table2_sigma

    def boom(tracker=None):
        raise RuntimeError("suite exploded")

    monkeypatch.setattr(table2_sigma, "bench", boom)
    rc = bench_run.main(["table2", "--out", str(tmp_path)])
    assert rc == 1
    out = capsys.readouterr().out
    assert "table2/FAILED,0,nan" in out  # per-suite row preserved
    assert not os.path.exists(os.path.join(tmp_path, "BENCH_table2.json"))


def test_run_writes_schema_valid_artifact(tmp_path, monkeypatch):
    from benchmarks import run as bench_run
    from benchmarks import table2_sigma

    monkeypatch.setattr(
        table2_sigma, "bench", lambda tracker=None: [("table2/x", 5.0, 1.25)]
    )
    rc = bench_run.main(["table2", "--out", str(tmp_path)])
    assert rc == 0
    doc = obs.load(os.path.join(tmp_path, "BENCH_table2.json"))
    assert obs.validate(doc) == []
    assert doc["metrics"]["table2/x"]["value"] == 1.25
    assert doc["gates"], "table2 artifact must carry regression gates"


# -- integration: algorithms + trainer telemetry -----------------------------


def test_marina_run_tracker_and_w2s_bits():
    from repro.core import marina_p, problems, stepsizes

    prob = problems.generate_problem(n=4, d=64, noise_scale=1.0, seed=0)
    tr = obs.MemoryTracker()
    h = marina_p.run(prob, mode="perm", k=16, p=0.25,
                     stepsize=stepsizes.Constant(0.02), T=8, tracker=tr)
    # uplink: one exact dense message (64 bits/coord) per round per worker
    assert h["w2s_bits"][-1] == pytest.approx(8 * 64 * prob.d)
    events = [e for e in tr.events if e["kind"] == "metrics"]
    assert len(events) == 8
    assert events[-1]["metrics"]["marina_p/w2s_bits"] == h["w2s_bits"][-1]
    assert "marina_p/s2w_bits" in events[0]["metrics"]


def test_ef21p_run_tracker_and_w2s_bits():
    from repro.core import compressors as C
    from repro.core import ef21p, problems, stepsizes

    prob = problems.generate_problem(n=4, d=64, noise_scale=1.0, seed=0)
    tr = obs.MemoryTracker()
    h = ef21p.run(prob, C.TopK(k=8), stepsizes.Constant(0.02), T=5, tracker=tr)
    assert h["w2s_bits"][-1] == pytest.approx(5 * 64 * prob.d)
    assert len([e for e in tr.events if e["kind"] == "metrics"]) == 5


def test_default_tracker_jsonl_env(tmp_path, monkeypatch):
    path = os.path.join(tmp_path, "stream.jsonl")
    monkeypatch.setenv("REPRO_OBS_JSONL", path)
    obs.reset_default_tracker()
    obs.default_tracker().log({"dryrun": {"t_compile_s": 1.5}})
    obs.reset_default_tracker()
    events = obs.read_jsonl(path)
    assert events[0]["metrics"] == {"dryrun/t_compile_s": 1.5}
    monkeypatch.delenv("REPRO_OBS_JSONL")
    obs.reset_default_tracker()
