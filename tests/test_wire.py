"""repro.wire: codec round trips, layout cross-checks, measured-vs-analytic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import wire
from repro.core import compressors as C
from repro.core import ef21p, marina_p, problems, stepsizes
from repro.kernels import ops, ref
from repro.serve.engine import apply_wire_delta
from repro.train.downlink import EF21PDownlink, MarinaPDownlink
from repro.wire import bitstream as bs


def _sparse_vec(d, nnz, seed=0):
    rng = np.random.default_rng(seed)
    x = np.zeros(d, np.float32)
    if nnz:
        idx = rng.choice(d, size=min(nnz, d), replace=False)
        x[idx] = rng.standard_normal(idx.size).astype(np.float32)
    return x


# ---------------------------------------------------------------------------
# bitstream layer: host == jnp ref == Pallas kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", [1, 7, 9, 10, 13, 16, 17, 31, 32])
def test_bitstream_roundtrip_and_cross_impl(width):
    rng = np.random.default_rng(width)
    n = 777
    vals = rng.integers(0, 2**width, n, dtype=np.uint64).astype(np.uint32)
    host = bs.pack_u32(vals, width)
    jref = np.asarray(ref.pack_bits_ref(jnp.asarray(vals), width))
    dev = np.asarray(ops.pack_bits(jnp.asarray(vals), width=width))
    np.testing.assert_array_equal(host, jref)
    np.testing.assert_array_equal(host, dev)
    np.testing.assert_array_equal(bs.unpack_u32(host, width, n), vals)
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_bits_ref(jnp.asarray(host), width, n)), vals
    )
    np.testing.assert_array_equal(
        np.asarray(ops.unpack_bits(jnp.asarray(host), width=width, count=n)), vals
    )


def test_bitstream_empty():
    assert bs.pack_u32(np.zeros(0, np.uint32), 9).size == 0
    assert bs.unpack_u32(np.zeros(0, "<u4"), 9, 0).size == 0


# ---------------------------------------------------------------------------
# sparse codec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,nnz", [(1000, 64), (1024, 1024), (7, 3), (129, 0), (1, 1)])
def test_sparse_roundtrip_fp32_exact(d, nnz):
    x = _sparse_vec(d, nnz, seed=d + nnz)
    got = wire.decode(wire.encode_sparse(x))
    np.testing.assert_array_equal(got, x)


@pytest.mark.parametrize("mag", ["fp16", "bf16"])
def test_sparse_roundtrip_reduced_mag(mag):
    """Reduced-precision magnitudes round-trip exactly when the input is
    already representable in the wire dtype."""
    import ml_dtypes

    dt = np.float16 if mag == "fp16" else np.dtype(ml_dtypes.bfloat16)
    x = _sparse_vec(512, 100, seed=3).astype(dt).astype(np.float32)
    got = wire.decode(wire.encode_sparse(x, mag=mag))
    np.testing.assert_array_equal(got, x)
    # and rounds (not corrupts) when it is not
    y = _sparse_vec(512, 100, seed=4)
    got = wire.decode(wire.encode_sparse(y, mag=mag))
    np.testing.assert_array_equal(got != 0, y != 0)
    np.testing.assert_allclose(got, y, rtol=2e-2 if mag == "bf16" else 1e-3)


def test_sparse_roundtrip_compressor_outputs():
    """decode(encode(q)) == q bit-for-bit for every sparse-family compressor."""
    d = 600  # not divisible by the blocktopk block
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))
    for comp in (C.TopK(k=32), C.BlockTopK(k_per_block=8, block=128), C.RandK(k=50)):
        q = np.asarray(comp(jax.random.PRNGKey(1), x), np.float32)
        got = wire.decode(wire.encode(q, comp))
        np.testing.assert_array_equal(got, q)
        assert wire.peek(wire.encode(q, comp))[0] == wire.CodecID.SPARSE


def test_dense_roundtrip():
    x = np.random.default_rng(0).standard_normal(257).astype(np.float32)
    np.testing.assert_array_equal(wire.decode(wire.encode_dense(x)), x)


# ---------------------------------------------------------------------------
# seed-only codec
# ---------------------------------------------------------------------------


def test_seed_bern_matches_counter_hash_kernel():
    delta = np.random.default_rng(1).standard_normal(512).astype(np.float32)
    msg = wire.SeedMessage(
        family=wire.SeedFamily.BERN, seed=11, round=0, scale=1.0, n=4, worker=2,
        param=0.25,
    )
    buf = wire.encode_seed(msg, 512)
    assert len(buf) == wire.HEADER_BYTES + 28  # O(1) regardless of d
    got = wire.decode(buf, delta=delta)
    want = np.asarray(ref.bernk_ref(jnp.asarray(delta), keep_prob=0.25, seed=11, worker=2))
    np.testing.assert_array_equal(got, want)


def test_seed_rotk_partition_identity():
    d, n = 96, 4
    delta = np.random.default_rng(2).standard_normal(d).astype(np.float32)
    acc = np.zeros(d, np.float32)
    for w in range(n):
        msg = wire.SeedMessage(
            family=wire.SeedFamily.ROTK, seed=0, round=0, scale=1.0, n=n, worker=w,
            param=3.0,  # shared rotation
        )
        acc += wire.decode(wire.encode_seed(msg, d), delta=delta)
    np.testing.assert_allclose(acc / n, delta, rtol=1e-6)


def test_seed_perm_matches_compressor():
    d, n = 64, 4
    delta = np.random.default_rng(3).standard_normal(d).astype(np.float32)
    for w in range(n):
        msg = wire.SeedMessage(
            family=wire.SeedFamily.PERM, seed=7, round=5, scale=1.0, n=n, worker=w
        )
        got = wire.decode(wire.encode_seed(msg, d), delta=delta)
        key = jax.random.fold_in(jax.random.PRNGKey(7), 5)
        want = np.asarray(C.PermK(n=n, worker=w)(key, jnp.asarray(delta)))
        np.testing.assert_array_equal(got, want)


def test_seed_requires_delta():
    buf = wire.encode_seed(
        wire.SeedMessage(wire.SeedFamily.BERN, 0, 0, 1.0, 1, 0, 0.5), 16
    )
    with pytest.raises(ValueError):
        wire.decode(buf)


# ---------------------------------------------------------------------------
# natural codec
# ---------------------------------------------------------------------------


def test_natural_roundtrip_exact_on_compressor_output():
    x = jax.random.normal(jax.random.PRNGKey(5), (777,))
    q = np.asarray(C.NaturalCompression()(jax.random.PRNGKey(6), x), np.float32)
    buf = wire.encode(q, C.NaturalCompression())
    assert wire.peek(buf)[0] == wire.CodecID.NATURAL
    np.testing.assert_array_equal(wire.decode(buf), q)
    # 9 bits/value + fixed header, matching CommModel.natural_bits
    from repro.core.comm_model import CommModel

    overhead = 8 * len(buf) - CommModel(d=777).natural_bits()
    assert 0 <= overhead <= 8 * wire.HEADER_BYTES + 32  # header + word padding


# ---------------------------------------------------------------------------
# measured vs analytic
# ---------------------------------------------------------------------------


def test_sparse_measured_bits_match_comm_model():
    from repro.core.comm_model import CommModel

    d, nnz = 1024, 128
    x = _sparse_vec(d, nnz, seed=9)
    measured = 8 * len(wire.encode_sparse(x))
    analytic = CommModel(d=d, value_bits=32).sparse_bits(nnz)
    overhead = measured - analytic
    assert 0 <= overhead <= 8 * (wire.HEADER_BYTES + 8) + 3 * 32  # headers + padding


@pytest.mark.parametrize("mode", ["same", "ind", "perm"])
def test_marina_run_wire_within_5pct(mode):
    prob = problems.generate_problem(n=4, d=256, noise_scale=1.0, seed=0)
    h = marina_p.run(
        prob, mode=mode, k=64, p=0.25, stepsize=stepsizes.Constant(gamma=0.02),
        T=30, measure_wire=True,
    )
    a, w = h["wire_model_ledger"].s2w_bits, h["wire_bits_total"]
    assert abs(w - a) / a < 0.05, (mode, a, w)
    # the budget-driving ledger keeps the paper's 64-bit model regardless
    assert h["ledger"].model.value_bits == 64


def test_ef21p_run_wire_overhead_bounded():
    prob = problems.generate_problem(n=4, d=256, noise_scale=1.0, seed=0)
    T = 20
    h = ef21p.run(
        prob, C.BlockTopK(k_per_block=16, block=128),
        stepsizes.Constant(gamma=0.02), T=T, measure_wire=True,
    )
    a, w = h["wire_model_ledger"].s2w_bits, h["wire_bits_total"]
    assert w >= a  # wire carries real headers
    assert (w - a) / T <= 8 * (wire.HEADER_BYTES + 8) + 3 * 32  # fixed per-round overhead


def test_downlink_measure_wire_matches_analytic():
    tree_new = {"w": jnp.arange(0, 2048, dtype=jnp.float32).reshape(16, 128) / 999.0,
                "b": jnp.linspace(-1, 1, 64)}
    tree_old = jax.tree.map(lambda t: t * 0.95, tree_new)
    for mode in ("perm", "ind", "same"):
        dl = MarinaPDownlink(n_workers=4, mode=mode, p=1e-9)  # force compress branch
        r = dl.measure_wire(jax.random.PRNGKey(0), tree_new, tree_old)
        assert not r["full_sync"]
        assert r["bits_seed"] < r["bits_mean"]  # O(1) vs O(q)
        assert abs(r["bits_mean"] - r["bits_analytic"]) / r["bits_analytic"] < 0.25
    dl = EF21PDownlink(n_workers=4, k_per_block=16, block=128)
    r = dl.measure_wire(jax.random.PRNGKey(0), tree_new, tree_old)
    assert r["bits_mean"] >= r["bits_analytic"]


# ---------------------------------------------------------------------------
# serve-side delta_sync
# ---------------------------------------------------------------------------


def test_apply_wire_delta_roundtrip():
    params = {"w": jnp.ones((8, 16)), "b": jnp.zeros((24,))}
    flat, _ = jax.flatten_util.ravel_pytree(params)
    delta = _sparse_vec(flat.size, 20, seed=13)
    new = apply_wire_delta(params, wire.encode_sparse(delta))
    flat_new, _ = jax.flatten_util.ravel_pytree(new)
    np.testing.assert_allclose(np.asarray(flat_new), np.asarray(flat) + delta, rtol=1e-6)
    # shape guard
    with pytest.raises(ValueError):
        apply_wire_delta(params, wire.encode_sparse(np.zeros(7, np.float32)))
    # SEED messages are rejected serving-side
    buf = wire.encode_seed(
        wire.SeedMessage(wire.SeedFamily.BERN, 0, 0, 1.0, 1, 0, 0.5), flat.size
    )
    with pytest.raises(ValueError):
        apply_wire_delta(params, buf)


def test_bad_magic_rejected():
    with pytest.raises(wire.CorruptFrame):
        wire.decode(b"\x00" * 16)


@pytest.mark.parametrize("make", [
    lambda: wire.encode_sparse(_sparse_vec(100, 10)),
    lambda: wire.encode_dense(np.ones(33, np.float32)),
    lambda: wire.encode_natural(np.zeros(50, np.float32)),
    lambda: wire.encode_seed(
        wire.SeedMessage(wire.SeedFamily.BERN, 0, 0, 1.0, 2, 0, 0.5), 64
    ),
])
def test_truncated_messages_rejected_cleanly(make):
    buf = make()
    for cut in (4, wire.HEADER_BYTES + 2, len(buf) - 1):
        with pytest.raises(wire.TruncatedFrame):
            wire.decode(buf[:cut], delta=np.ones(64, np.float32))


def test_corrupt_index_rejected():
    """An index bit-flipped past d must raise CorruptFrame, not IndexError."""
    d = 100  # index_width(100)=7, so 127 is representable but out of range
    x = np.zeros(d, np.float32)
    x[5] = 1.0
    buf = bytearray(wire.encode_sparse(x))
    payload = wire.HEADER_BYTES + 8  # common header + sparse payload header
    buf[payload] = 127  # first 7-bit index -> 127
    with pytest.raises(wire.CorruptFrame, match="corrupt"):
        wire.decode(bytes(buf))
