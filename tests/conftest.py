import os

# Tests run single-device (the dry-run, and only the dry-run, forces 512
# placeholder devices in its own process — see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_default_matmul_precision", "highest")
