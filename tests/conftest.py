import os
import random
import sys
import types

# Tests run single-device (the dry-run, and only the dry-run, forces 512
# placeholder devices in its own process — see launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_default_matmul_precision", "highest")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: slow / interpret-mode Pallas tests (deselect with -m 'not slow')"
    )


# ---------------------------------------------------------------------------
# hypothesis fallback: the container may not ship hypothesis. The property
# tests only use integers()/sampled_from(), so a deterministic re-sampling
# stand-in preserves their coverage instead of dying at collection.
# ---------------------------------------------------------------------------

try:  # pragma: no cover - exercised only where hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value=0, max_value=2**31 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(seq):
        choices = list(seq)
        return _Strategy(lambda rng: rng.choice(choices))

    def _settings(max_examples=10, deadline=None, **_):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    def _given(**strats):
        def deco(fn):
            def run():
                n = getattr(run, "_stub_max_examples", 10)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(**drawn)

            # zero-arg signature: the strategy kwargs must not look like
            # pytest fixtures (functools.wraps would re-expose them)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
