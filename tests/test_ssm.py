"""Chunked Mamba2/RWKV6 forward == naive sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import ssm
from repro.models.config import MambaConfig, ModelConfig, RWKVConfig


def _mamba_cfg(chunk):
    return ModelConfig(
        arch_id="t", family="ssm", num_layers=1, d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=64,
        block_pattern=("mamba",),
        mamba=MambaConfig(state_dim=8, head_dim=32, expand=2, chunk=chunk, conv_width=4),
    )


def test_mamba_chunked_equals_sequential_decode():
    """Prefill (chunked SSD) must equal running decode step by step."""
    cfg = _mamba_cfg(chunk=8)
    key = jax.random.PRNGKey(0)
    params = ssm.mamba_init(cfg, key)
    B, S = 2, 32
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model), jnp.float32) * 0.5
    y_chunked = ssm.mamba_apply(cfg, params, x)
    cache = ssm.mamba_cache_init(cfg, B)
    ys = []
    for t in range(S):
        yt, cache = ssm.mamba_decode(cfg, params, x[:, t : t + 1], cache, jnp.int32(t))
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_chunked, np.float32), np.asarray(y_seq, np.float32), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("chunks", [(8, 16)])
def test_mamba_chunk_size_invariance(chunks):
    c1, c2 = chunks
    key = jax.random.PRNGKey(2)
    B, S = 1, 32
    cfg1, cfg2 = _mamba_cfg(c1), _mamba_cfg(c2)
    params = ssm.mamba_init(cfg1, key)
    x = jax.random.normal(jax.random.fold_in(key, 3), (B, S, cfg1.d_model)) * 0.5
    y1 = ssm.mamba_apply(cfg1, params, x)
    y2 = ssm.mamba_apply(cfg2, params, x)
    np.testing.assert_allclose(np.asarray(y1, np.float32), np.asarray(y2, np.float32), rtol=1e-3, atol=1e-3)


def _rwkv_cfg():
    return ModelConfig(
        arch_id="t", family="ssm", num_layers=1, d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=64,
        block_pattern=("rwkv",), use_rope=False,
        rwkv=RWKVConfig(head_dim=32, decay_lora=8),
    )


def test_rwkv_chunked_equals_sequential_decode():
    cfg = _rwkv_cfg()
    key = jax.random.PRNGKey(4)
    params = ssm.rwkv_init(cfg, key)
    B, S = 2, 64  # 2 chunks of 32
    x = jax.random.normal(jax.random.fold_in(key, 5), (B, S, cfg.d_model), jnp.float32) * 0.5
    y_chunked = ssm.rwkv_timemix_apply(cfg, params, x)
    cache = ssm.rwkv_cache_init(cfg, B)
    ys = []
    c = {"state": cache["state"], "x_last": cache["x_last"]}
    for t in range(S):
        yt, c = ssm.rwkv_timemix_decode(cfg, params, x[:, t : t + 1], c, jnp.int32(t))
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_chunked, np.float32), np.asarray(y_seq, np.float32), rtol=3e-2, atol=3e-2
    )


def test_rwkv_decay_clamped():
    """log w must live in [RWKV_LOGW_MIN, RWKV_LOGW_MAX] (stability contract)."""
    cfg = _rwkv_cfg()
    params = ssm.rwkv_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model)) * 10.0
    rv, kv, vv, logw, g = ssm._rwkv_proj(cfg, params, x, ssm._shift(x))
    lw = np.asarray(logw, np.float32)
    assert (lw >= ssm.RWKV_LOGW_MIN - 1e-6).all() and (lw <= 0).all()


def test_mamba_state_shape():
    cfg = _mamba_cfg(8)
    cache = ssm.mamba_cache_init(cfg, batch=3)
    d_inner = cfg.mamba.expand * cfg.d_model
    H = d_inner // cfg.mamba.head_dim
    assert cache["ssm"].shape == (3, H, cfg.mamba.state_dim, cfg.mamba.head_dim)
    assert cache["conv"].shape == (3, cfg.mamba.conv_width - 1, d_inner + 2 * cfg.mamba.state_dim)
