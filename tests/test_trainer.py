"""LM trainer + downlink integration, checkpoint/data/serve substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import SyntheticLMData, batch_specs
from repro.models import lm
from repro.optim import make_optimizer
from repro.optim.schedules import constant_lr, cosine_warmup, inv_sqrt
from repro.serve import DecodeEngine
from repro.train import TrainerConfig, init_state, make_downlink, make_train_step
from repro.train.downlink import MarinaPDownlink, tree_size


@pytest.fixture(scope="module")
def small():
    cfg = configs.get_smoke("gemma-2b")
    tcfg = TrainerConfig(n_workers=2, attn_chunk=32)
    return cfg, tcfg


def _run(cfg, tcfg, spec, steps=8, polyak=0.0):
    if polyak:
        tcfg = TrainerConfig(n_workers=tcfg.n_workers, attn_chunk=tcfg.attn_chunk,
                             polyak_factor=polyak)
    dl = make_downlink(spec, tcfg.n_workers)
    opt = make_optimizer("adamw")
    state = init_state(cfg, tcfg, dl, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg, dl, opt, constant_lr(2e-3)))
    data = SyntheticLMData(cfg, tcfg.n_workers, 2, 64)
    hist = []
    for i in range(steps):
        state, m = step(state, data.batch(i), jax.random.fold_in(jax.random.PRNGKey(9), i))
        hist.append(float(m["loss"]))
    return state, hist, m


@pytest.mark.parametrize("spec", ["marina:perm", "marina:ind", "marina:same", "ef21p:16:64", "none"])
def test_loss_decreases_all_downlinks(small, spec):
    cfg, tcfg = small
    _, hist, _ = _run(cfg, tcfg, spec, steps=10)
    assert hist[-1] < hist[0], (spec, hist)
    assert not any(np.isnan(hist))


def test_polyak_lr_runs(small):
    cfg, tcfg = small
    _, hist, m = _run(cfg, tcfg, "marina:perm", steps=6, polyak=0.5)
    assert float(m["lr"]) > 0 and not np.isnan(hist[-1])


def test_marina_workers_average_tracks_server(small):
    """RotK exact-mean: mean_i w_i == x after a no-sync round."""
    cfg, tcfg = small
    dl = MarinaPDownlink(n_workers=4, mode="perm", p=1e-9)  # never full-sync
    server = lm.lm_init(cfg, jax.random.PRNGKey(0))
    workers = dl.init_workers(server)
    delta_tree = jax.tree.map(lambda t: jnp.ones_like(t) * 0.01, server)
    server_new = jax.tree.map(lambda a, b: a + b, server, delta_tree)
    new_workers, bits = dl.round(jax.random.PRNGKey(1), server_new, server, workers)
    mean_w = jax.tree.map(lambda w: jnp.mean(w, axis=0), new_workers)
    err = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(mean_w), jax.tree.leaves(server_new))
    )
    assert err < 1e-5
    assert float(bits) > 0


def test_ef21p_downlink_drift_contracts(small):
    """Repeated rounds at a fixed server point must contract the shift error."""
    cfg, _ = small
    from repro.train.downlink import EF21PDownlink

    dl = EF21PDownlink(n_workers=2, k_per_block=16, block=64)
    server = lm.lm_init(cfg, jax.random.PRNGKey(0))
    target = jax.tree.map(lambda t: t + 0.1, server)
    shift = dl.init_shift(server)
    drifts = []
    for i in range(6):
        shift, _ = dl.round(jax.random.PRNGKey(i), target, shift)
        drifts.append(float(dl.worker_drift(target, shift)))
    assert drifts[-1] < 0.3 * drifts[0]


def test_bits_accounting_formula(small):
    cfg, tcfg = small
    dl = make_downlink("marina:perm", 2)
    d = tree_size(lm.lm_init(cfg, jax.random.PRNGKey(0)))
    state, hist, m = _run(cfg, tcfg, "marina:perm", steps=4)
    bits = float(m["bits_per_worker"])
    # between 4 sparse rounds and 4 dense rounds
    import math
    lo = 4 * (65 + math.log2(d)) * d / 2 * 0.9
    hi = 4 * 64.0 * d * 1.1
    assert lo <= bits <= hi


# -- substrate ---------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path, small):
    cfg, _ = small
    params = lm.lm_init(cfg, jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, step=7, extra={"arch": cfg.arch_id})
    restored, meta = load_checkpoint(path, params)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_deterministic_and_sharded(small):
    cfg, _ = small
    data = SyntheticLMData(cfg, n_workers=3, batch_per_worker=2, seq_len=32)
    b1, b2 = data.batch(5), data.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (3, 2, 32)
    b3 = data.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < cfg.vocab_size


def test_batch_specs_match_data(small):
    cfg, _ = small
    spec = batch_specs(cfg, 3, 2, 32)
    data = SyntheticLMData(cfg, 3, 2, 32).batch(0)
    assert jax.tree.structure(spec) == jax.tree.structure(data)
    for s, d in zip(jax.tree.leaves(spec), jax.tree.leaves(data)):
        assert s.shape == d.shape and s.dtype == d.dtype


def test_serve_engine_greedy_deterministic(small):
    cfg, _ = small
    params = lm.lm_init(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, cache_len=64, batch_size=2)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    t1 = eng.run(prompts, n_new_tokens=6)
    t2 = eng.run(prompts, n_new_tokens=6)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert t1.shape == (2, 6)


def test_serve_engine_latency_telemetry(small):
    """Each request logs prefill/decode timers + a tokens/s metric."""
    from repro import obs

    cfg, _ = small
    params = lm.lm_init(cfg, jax.random.PRNGKey(0))
    tr = obs.MemoryTracker()
    eng = DecodeEngine(cfg, params, cache_len=64, batch_size=2, tracker=tr)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    eng.run(prompts, n_new_tokens=4)
    eng.run(prompts, n_new_tokens=4)
    timers = [e["name"] for e in tr.events if e["kind"] == "timer"]
    assert timers == ["serve/prefill", "serve/decode"] * 2
    mets = [e for e in tr.events if e["kind"] == "metrics"]
    assert len(mets) == 2
    assert mets[0]["metrics"]["serve/tokens_per_s"] > 0
    assert mets[0]["metrics"]["serve/batch"] == 2


def test_train_loop_tracker_and_uplink_bits(small):
    """train_loop logs step timers + metrics; uplink accrues dense bits/step."""
    import math

    from repro import obs
    from repro.data import SyntheticLMData
    from repro.optim import make_optimizer
    from repro.train import train_loop

    cfg, tcfg = small
    dl = make_downlink("marina:perm", tcfg.n_workers)
    tr = obs.MemoryTracker()
    data = SyntheticLMData(cfg, tcfg.n_workers, 2, 64)
    state, m = train_loop(
        cfg, tcfg, dl, make_optimizer("adamw"), constant_lr(2e-3), data,
        steps=3, key=jax.random.PRNGKey(0), tracker=tr,
    )
    timers = [e for e in tr.events if e["kind"] == "timer"]
    assert [t["name"] for t in timers] == ["train/step"] * 3
    assert all(t["seconds"] > 0 for t in timers)
    mets = [e for e in tr.events if e["kind"] == "metrics"]
    d = tree_size(state["server"])
    # uplink = one exact dense (64-bit model) gradient per worker per step
    assert float(m["uplink_bits_per_worker"]) == pytest.approx(3 * 64.0 * d, rel=1e-6)
    assert mets[-1]["metrics"]["train/uplink_bits_per_worker"] == pytest.approx(
        float(m["uplink_bits_per_worker"]), rel=1e-6
    )
    assert "train/loss" in mets[0]["metrics"]


def test_lr_schedules():
    sch = cosine_warmup(1.0, warmup=10, total=100)
    assert float(sch(jnp.int32(0))) == 0.0
    assert float(sch(jnp.int32(10))) == pytest.approx(1.0)
    assert float(sch(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)
    d = inv_sqrt(2.0)
    assert float(d(jnp.int32(3))) == pytest.approx(1.0)


def test_optimizers_step():
    from repro.optim import adamw_init, adamw_update, sgd_init, sgd_update

    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,))}
    st = adamw_init(params)
    p2, st = adamw_update(grads, st, params, 0.1)
    assert float(p2["w"][0]) < 1.0
    st2 = sgd_init(params, momentum=0.9)
    p3, st2 = sgd_update(grads, st2, params, 0.1, momentum=0.9)
    np.testing.assert_allclose(np.asarray(p3["w"]), 0.9)
