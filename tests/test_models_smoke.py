"""Per-architecture smoke tests (mandated): reduced same-family variant,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data import SyntheticLMData
from repro.models import lm
from repro.optim import make_optimizer
from repro.optim.schedules import constant_lr
from repro.train import TrainerConfig, init_state, make_downlink, make_train_step

ARCHS = list(configs.ALIASES)


def _batch(cfg, key, B=2, S=64):
    if cfg.num_codebooks:
        return {"tokens": jax.random.randint(key, (B, cfg.num_codebooks, S), 0, cfg.vocab_size)}
    if cfg.num_patches:
        return {
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "patches": jax.random.normal(key, (B, cfg.num_patches, cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = configs.get_smoke(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = lm.lm_init(cfg, key)
    batch = _batch(cfg, key)
    logits = jax.jit(lambda p, b: lm.forward(cfg, p, b, chunk=32))(params, batch)
    B, S = 2, 64
    if cfg.num_codebooks:
        assert logits.shape == (B, cfg.num_codebooks, S, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_no_nan(arch):
    cfg = configs.get_smoke(arch)
    tcfg = TrainerConfig(n_workers=2, attn_chunk=32)
    dl = make_downlink("marina:perm", 2)
    opt = make_optimizer("adamw")
    state = init_state(cfg, tcfg, dl, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg, dl, opt, constant_lr(1e-3)))
    data = SyntheticLMData(cfg, 2, 2, 64)
    l0 = None
    for i in range(3):
        state, m = step(state, data.batch(i), jax.random.fold_in(jax.random.PRNGKey(1), i))
        assert not bool(jnp.isnan(m["loss"]))
        if l0 is None:
            l0 = float(m["loss"])
    assert float(m["loss"]) < l0 + 1.0  # sane trajectory (not exploding)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact assigned hyperparameters."""
    cfg = configs.get(arch)
    expect = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == expect, (arch, got, expect)
    if arch == "deepseek-v2-236b":
        assert cfg.moe.num_experts == 160 and cfg.moe.top_k == 6 and cfg.moe.num_shared == 2
        assert cfg.mla.kv_lora_rank == 512 and cfg.moe.d_ff_expert == 1536
    if arch == "llama4-maverick-400b-a17b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 1
    if arch == "zamba2-1.2b":
        assert cfg.mamba.state_dim == 64
    if arch == "gemma3-1b":
        pattern = cfg.block_pattern
        assert sum(k == "attn" for k in pattern) * 5 <= sum(k == "attn_local" for k in pattern) + 5
    if arch == "musicgen-large":
        assert cfg.num_codebooks == 4
    if arch == "rwkv6-1.6b":
        assert all(k == "rwkv" for k in cfg.block_pattern)


def test_param_counts_in_family_range():
    """Total params should be within ~35% of the nameplate size."""
    expect = {
        "zamba2-1.2b": 1.2e9,
        "starcoder2-7b": 7e9,
        "gemma-2b": 2.5e9,
        "deepseek-v2-236b": 236e9,
        "musicgen-large": 3.3e9,
        "llama4-maverick-400b-a17b": 400e9,
        "gemma3-1b": 1.0e9,
        "pixtral-12b": 12e9,
        "rwkv6-1.6b": 1.6e9,
        "minitron-4b": 4e9,
    }
    for arch, n in expect.items():
        cfg = configs.get(arch)
        got = cfg.param_count()
        assert 0.5 * n < got < 1.6 * n, (arch, got, n)
