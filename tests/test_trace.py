"""Span tracing (repro.obs.trace), analyzer, histogram, and export tests.

DESIGN.md §10 acceptance: seeded chaos runs produce deterministic span
trees, spans survive CompositeTracker fan-out and a JSONL round-trip,
the analyzer validates/attributes/exports them, and the streaming
histogram replaces the biased first-N reservoir.
"""
import json
import math
import os

import numpy as np
import pytest

from repro import obs
from repro.core import marina_p, problems, stepsizes
from repro.obs import analyze
from repro.obs.hist import StreamingHistogram, percentile
from repro.transport import FaultSpec

CHAOS = FaultSpec(drop=0.3, straggler=0.3, straggler_ticks=3, seed=7)


@pytest.fixture(scope="module")
def prob():
    return problems.generate_problem(n=4, d=32, noise_scale=1.0, seed=0)


def _chaos_run(prob, tracker, *, seed=1, T=10):
    k = prob.d // prob.n
    p = k / prob.d
    return marina_p.run(prob, mode="perm", k=k, p=p,
                        stepsize=stepsizes.Constant(gamma=0.01), T=T,
                        seed=seed, transport=CHAOS, tracker=tracker)


# -- span API -----------------------------------------------------------------


def test_span_nesting_and_ids():
    tr = obs.MemoryTracker()
    with tr.span("round", round=0) as rsp:
        with tr.span("broadcast"):
            with tr.span("encode"):
                pass
        rsp.attrs["gamma"] = 0.5
    with tr.span("round", round=1):
        pass
    spans = analyze.span_events(tr.events)
    # emitted at exit: children before parents
    assert [s["name"] for s in spans] == ["encode", "broadcast", "round", "round"]
    by_name = {s["name"]: s for s in spans[:3]}
    assert by_name["broadcast"]["parent"] == by_name["round"]["span_id"]
    assert by_name["encode"]["parent"] == by_name["broadcast"]["span_id"]
    assert by_name["round"]["parent"] is None
    # deterministic counter ids, attrs mutable until exit
    assert [s["span_id"] for s in spans] == [2, 1, 0, 3]
    assert by_name["round"]["attrs"] == {"round": 0, "gamma": 0.5}
    assert all(s["t1"] >= s["t0"] for s in spans)


def test_maybe_span_none_tracker_is_noop():
    from repro.obs.trace import maybe_attr, maybe_span

    with maybe_span(None, "round") as sp:
        assert sp is None
        maybe_attr(sp, x=1)  # must not raise


def test_span_composite_fanout_and_jsonl_roundtrip(tmp_path):
    log = tmp_path / "run.jsonl"
    mem = obs.MemoryTracker()
    jl = obs.JsonlTracker(str(log))
    comp = obs.CompositeTracker(mem, jl)
    with comp.span("round", round=0):
        with comp.span("broadcast", full_sync=False):
            pass
    comp.finish()
    assert obs.events_equal(mem.events, obs.read_jsonl(str(log)))
    spans = analyze.span_events(obs.read_jsonl(str(log)))
    assert [s["name"] for s in spans] == ["broadcast", "round"]
    assert spans[0]["attrs"] == {"full_sync": False}


# -- determinism under fault injection ---------------------------------------


def test_chaos_span_tree_deterministic(prob):
    """Same transport/algorithm seed => identical span tree (names,
    nesting, retry/resync/delivery attrs); only timestamps differ."""
    t1, t2 = obs.MemoryTracker(), obs.MemoryTracker()
    _chaos_run(prob, t1)
    _chaos_run(prob, t2)
    assert obs.events_equal(t1.events, t2.events)
    f1 = analyze.build_tree(t1.events)
    f2 = analyze.build_tree(t2.events)
    assert [r.signature() for r in f1] == [r.signature() for r in f2]
    # a different seed must actually change the tree (retries differ)
    t3 = obs.MemoryTracker()
    _chaos_run(prob, t3, seed=2)
    assert [r.signature() for r in f1] != [
        r.signature() for r in analyze.build_tree(t3.events)
    ]


def test_chaos_spans_carry_link_attribution(prob):
    tr = obs.MemoryTracker()
    _chaos_run(prob, tr)
    roots = analyze.build_tree(tr.events)
    assert all(r.name == "round" for r in roots)
    names = {s.name for r in roots for s in r.walk()}
    assert {"round", "subgrad", "stepsize", "broadcast", "encode"} <= names
    assert any(n.startswith("link/worker") for n in names)
    links = [s for r in roots for s in r.walk()
             if s.name.startswith("link/worker") and "/" not in s.name[5:]]
    assert links and all("delivered" in s.attrs and "retries" in s.attrs
                         for s in links)
    # the chaos spec must actually exercise the repair paths
    assert sum(int(s.attrs["retries"]) for s in links) > 0


def test_round_reports_attribute_degraded_rounds(prob):
    tr = obs.MemoryTracker()
    _chaos_run(prob, tr)
    reports = analyze.round_reports(analyze.build_tree(tr.events))
    assert len(reports) == 10
    degraded = [r for r in reports if r.degraded]
    assert degraded, "chaos spec produced no degraded round"
    assert all(r.culprit.startswith("link/worker") for r in degraded)
    text, n_degraded = analyze.report(tr.events)
    assert n_degraded == len(degraded)
    assert "DEGRADED <- link/worker" in text


# -- validation + Perfetto export ---------------------------------------------


def test_validate_spans_catches_malformed_streams():
    ok = {"kind": "span", "name": "a", "span_id": 0, "parent": None,
          "t0": 1.0, "t1": 2.0, "attrs": {}}
    assert analyze.validate_spans([ok]) == []
    orphan = dict(ok, span_id=1, parent=99)
    assert any("orphan parent" in e for e in analyze.validate_spans([ok, orphan]))
    backwards = dict(ok, span_id=2, t0=3.0, t1=1.0)
    assert any("t1 < t0" in e for e in analyze.validate_spans([backwards]))
    dup = dict(ok)
    assert any("duplicate span_id" in e for e in analyze.validate_spans([ok, dup]))
    missing = {"kind": "span", "name": "a", "span_id": 3}
    assert any("missing t0" in e for e in analyze.validate_spans([missing]))


def test_perfetto_export_well_formed(prob, tmp_path):
    tr = obs.MemoryTracker()
    _chaos_run(prob, tr, T=4)
    doc = analyze.to_perfetto(tr.events)
    assert analyze.validate_perfetto(doc) == []
    assert doc["displayTimeUnit"] == "ms"
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == len(analyze.span_events(tr.events))
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in xs)
    assert all(e["pid"] == 1 and e["tid"] == 1 for e in xs)
    # span ids + parentage travel in args for trace-query reconstruction
    with_parent = [e for e in xs if "parent" in e["args"]]
    assert with_parent and all("span_id" in e["args"] for e in xs)
    # document is valid JSON end to end
    out = tmp_path / "trace.json"
    out.write_text(json.dumps(doc))
    assert analyze.validate_perfetto(json.loads(out.read_text())) == []
    bad = {"traceEvents": [{"ph": "X", "name": "x", "ts": 0.0, "dur": -1.0,
                            "pid": 1, "tid": 1}]}
    assert any("negative" in e for e in analyze.validate_perfetto(bad))


def test_analyze_cli_end_to_end(prob, tmp_path, capsys):
    log = tmp_path / "run.jsonl"
    tr = obs.JsonlTracker(str(log))
    _chaos_run(prob, tr)
    tr.finish()
    trace = tmp_path / "trace.json"
    rc = analyze.main([str(log), "--perfetto", str(trace), "--require-degraded"])
    assert rc == 0
    assert os.path.exists(trace)
    assert analyze.main(["--validate-trace", str(trace)]) == 0
    out = capsys.readouterr().out
    assert "round" in out and "DEGRADED" in out
    # an empty log has no degraded rounds to attribute
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert analyze.main([str(empty), "--require-degraded"]) == 1


# -- percentiles / histogram (bench_json reservoir-bias fix) ------------------


def test_percentile_linear_interpolation():
    vals = sorted(float(v) for v in range(100))  # 0..99
    assert percentile(vals, 0.0) == 0.0
    assert percentile(vals, 1.0) == 99.0
    assert percentile(vals, 0.50) == pytest.approx(49.5)  # not nearest-rank 50
    assert percentile(vals, 0.99) == pytest.approx(98.01)
    assert percentile([5.0], 0.75) == 5.0


def test_streaming_histogram_exact_below_cap():
    h = StreamingHistogram(exact_cap=1000)
    vals = list(np.random.default_rng(0).normal(10.0, 2.0, 500))
    for v in vals:
        h.add(v)
    s = sorted(vals)
    assert h.quantile(0.5) == pytest.approx(percentile(s, 0.5))
    assert h.quantile(0.99) == pytest.approx(percentile(s, 0.99))
    assert h.n == 500


def test_streaming_histogram_sees_past_cap():
    """The old reservoir kept only the first 4096 samples — a later shift
    in the distribution never moved p99. The histogram tracks it."""
    h = StreamingHistogram(exact_cap=256)
    for _ in range(256):
        h.add(1e-3)  # warm-up plateau fills the exact window
    for _ in range(4096):
        h.add(1.0)   # steady state is 1000x slower
    q = h.quantile(0.99)
    assert q == pytest.approx(1.0, rel=0.05)
    assert h.n == 4352 and h.max >= 1.0
    # relative accuracy of the log-binned estimate
    h2 = StreamingHistogram(exact_cap=64)
    data = np.random.default_rng(1).lognormal(0.0, 1.0, 20000)
    for v in data:
        h2.add(float(v))
    for q_ in (0.5, 0.99):
        ref = float(np.quantile(data, q_))
        assert h2.quantile(q_) == pytest.approx(ref, rel=0.05)


def test_streaming_histogram_ignores_nan_and_summary():
    h = StreamingHistogram()
    h.add(float("nan"))
    for v in (0.5, -2.0, 3.0):
        h.add(v)
    assert h.n == 3
    s = h.summary("_s")
    assert s["n"] == 3
    assert s["total_s"] == pytest.approx(1.5)
    assert s["p50_s"] == pytest.approx(0.5)


def test_bench_sink_aggregates_spans_as_namespaced_timers(tmp_path):
    sink = obs.BenchJsonSink("t", str(tmp_path))
    with sink.span("round"):
        pass
    with sink.time_block("round"):
        pass
    sink.finish()
    doc = obs.load(sink.path)
    assert "span/round" in doc["timers"] and "round" in doc["timers"]
    from repro.obs import bench_json

    assert bench_json.validate(doc) == []


# -- profile event ------------------------------------------------------------


def test_profile_emits_trace_dir_event(tmp_path):
    tr = obs.MemoryTracker()
    with tr.profile("step", trace_dir=str(tmp_path)):
        import jax.numpy as jnp

        jnp.ones(4).block_until_ready()
    profs = [e for e in tr.events if e["kind"] == "profile"]
    assert len(profs) == 1
    assert profs[0]["name"] == "step"
    assert profs[0]["trace_dir"] == os.path.join(str(tmp_path), "step")
    assert os.path.isdir(profs[0]["trace_dir"])
    # no trace dir configured -> no-op, no event
    tr2 = obs.MemoryTracker()
    env = os.environ.pop("REPRO_OBS_TRACE_DIR", None)
    try:
        with tr2.profile("step"):
            pass
    finally:
        if env is not None:
            os.environ["REPRO_OBS_TRACE_DIR"] = env
    assert tr2.events == []


# -- fleet cohort spans -------------------------------------------------------


def test_fleet_run_spans_attribute_dropped_slots():
    from repro.core import stepsizes as ss
    from repro.fleet import make_fleet, make_sampler
    from repro.fleet.cohort import fleet_run
    from repro.fleet.population import FleetL1Problem

    spec = make_fleet("flaky_mobile", 512, seed=0)
    prob = FleetL1Problem(spec, d=32)
    sampler = make_sampler("uniform", spec, 8, seed=1)
    tr = obs.MemoryTracker()
    fleet_run(prob, sampler, ss.Constant(gamma=0.05), algorithm="marina_p",
              mode="perm", T=8, seed=0, tracker=tr)
    assert analyze.validate_spans(tr.events) == []
    roots = analyze.build_tree(tr.events)
    reports = analyze.round_reports(roots)
    assert len(reports) == 8
    # flaky_mobile's per-client drop model must surface as degraded rounds
    # attributed to specific client links with fresh/delivered attrs
    degraded = [r for r in reports if r.degraded]
    assert degraded and all(r.culprit.startswith("link/client") for r in degraded)
    links = [s for r in roots for s in r.walk() if s.name.startswith("link/client")]
    assert links and all(
        "delivered" in s.attrs and "fresh" in s.attrs for s in links
    )


# -- serve spans --------------------------------------------------------------


def test_serve_request_spans(tmp_path):
    import jax

    from repro import configs
    from repro.models import lm
    from repro.serve import DecodeEngine

    cfg = configs.get_smoke("gemma-2b")
    params = lm.lm_init(cfg, jax.random.PRNGKey(0))
    tr = obs.MemoryTracker()
    eng = DecodeEngine(cfg, params, cache_len=16, batch_size=2, tracker=tr)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    eng.run(prompts, n_new_tokens=4)
    roots = analyze.build_tree(tr.events)
    reqs = [r for r in roots if r.name == "serve/request"]
    assert len(reqs) == 1
    assert [c.name for c in reqs[0].children] == ["prefill", "decode"]
    assert reqs[0].attrs["tokens_per_s"] > 0
    assert reqs[0].attrs["batch"] == 2
    # serve/request rounds get latency reports too
    reports = analyze.round_reports(roots)
    assert len(reports) == 1 and not reports[0].degraded
    # existing timer telemetry is untouched by the spans
    timers = [e["name"] for e in tr.events if e["kind"] == "timer"]
    assert timers == ["serve/prefill", "serve/decode"]
