"""Sharding rules: every generated PartitionSpec divides its leaf's shape,
for all 10 architectures and all layouts, on both production meshes."""
import types

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import sharding as sh
from repro.models import lm

SINGLE = types.SimpleNamespace(shape={"data": 16, "model": 16}, axis_names=("data", "model"))
MULTI = types.SimpleNamespace(
    shape={"pod": 2, "data": 16, "model": 16}, axis_names=("pod", "data", "model")
)


def _axis_size(mesh, ax):
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def _check_specs(tree_shape, specs, mesh, expect_leading_worker=False):
    leaves_s, _ = jax.tree_util.tree_flatten(tree_shape)
    leaves_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(leaves_s) == len(leaves_p)
    for leaf, spec in zip(leaves_s, leaves_p):
        assert len(spec) <= len(leaf.shape), (spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            assert dim % _axis_size(mesh, ax) == 0, (spec, leaf.shape)
        if expect_leading_worker and leaf.shape:
            assert tuple(spec) and tuple(spec)[0] is not None


@pytest.mark.parametrize("arch", list(configs.ALIASES))
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["16x16", "2x16x16"])
def test_param_specs_divisible(arch, mesh):
    cfg = configs.get(arch)
    shape = jax.eval_shape(lambda k: lm.lm_init(cfg, k), jax.random.PRNGKey(0))
    for layout in ("server", "serve"):
        specs = sh.param_specs(shape, mesh, layout)
        _check_specs(shape, specs, mesh)
    W = 32 if "pod" in mesh.axis_names else 16
    wshape = jax.tree.map(lambda l: jax.ShapeDtypeStruct((W,) + l.shape, l.dtype), shape)
    wspecs = sh.param_specs(wshape, mesh, "worker")
    _check_specs(wshape, wspecs, mesh, expect_leading_worker=True)


def test_tensor_parallel_covers_big_leaves():
    """The bulk of parameter bytes must actually be model-sharded."""
    cfg = configs.get("starcoder2-7b")
    shape = jax.eval_shape(lambda k: lm.lm_init(cfg, k), jax.random.PRNGKey(0))
    specs = sh.param_specs(shape, SINGLE, "server")
    leaves_s = jax.tree.leaves(shape)
    leaves_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    sharded = sum(
        l.size for l, p in zip(leaves_s, leaves_p) if any(a == "model" for a in tuple(p))
    )
    total = sum(l.size for l in leaves_s)
    assert sharded / total > 0.95


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["16x16", "2x16x16"])
def test_cache_specs(mesh):
    cfg = configs.get_smoke("gemma-2b")
    # decode_32k-style: batch divisible
    caches = jax.eval_shape(lambda: lm.cache_init(cfg, 128, 1024))
    specs = sh.cache_specs(caches, mesh, 128)
    _check_specs(caches, specs, mesh)
    # long_500k-style: batch 1 -> sequence dim sharded
    caches1 = jax.eval_shape(lambda: lm.cache_init(cfg, 1, 8192))
    specs1 = sh.cache_specs(caches1, mesh, 1)
    _check_specs(caches1, specs1, mesh)
    flat = jax.tree.leaves(specs1, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert any(any(a is not None for a in tuple(p)) for p in flat), "seq dim should shard"


def test_moe_expert_parallel():
    cfg = configs.get("deepseek-v2-236b")
    shape = jax.eval_shape(lambda k: lm.lm_init(cfg, k), jax.random.PRNGKey(0))
    specs = sh.param_specs(shape, SINGLE, "server")
    found = []

    def visit(path, spec):
        ps = sh._path_str(path)
        if "moe" in ps and "w_in" in ps and "shared" not in ps:
            found.append(tuple(spec))

    jax.tree_util.tree_map_with_path(
        visit, specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    assert found and all("model" in sp for sp in found), found
