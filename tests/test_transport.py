"""repro.transport: framing, fault injection, recovery, degraded training.

Covers DESIGN.md §8 end to end: CRC32C frames survive (or cleanly reject)
every fault class, reliable links deliver in order under heavy seeded
faults, MARINA-P / EF21-P runs complete and converge through a degraded
fleet, and the serving endpoint refuses stale / out-of-order deltas.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import obs, transport, wire
from repro.core import problems, stepsizes
from repro.core import compressors as C
from repro.core import ef21p, marina_p
from repro.transport import (
    FAULT_CLASSES,
    FaultInjector,
    FaultSpec,
    FaultyChannel,
    Fleet,
    FrameType,
    Link,
    LoopbackChannel,
    SequenceGap,
    StaleDelta,
    crc32c,
    decode_frame,
    encode_frame,
    is_frame,
)


# ---------------------------------------------------------------------------
# CRC32C + frame codec
# ---------------------------------------------------------------------------


def test_crc32c_reference_vector():
    """RFC 3720 B.4: crc32c("123456789") == 0xE3069283."""
    assert crc32c(b"123456789") == 0xE3069283
    assert crc32c(b"") == 0


def test_crc32c_incremental_matches_oneshot():
    data = bytes(range(256)) * 5
    assert crc32c(data[100:], crc32c(data[:100])) == crc32c(data)


def test_frame_roundtrip_all_types():
    for ftype in FrameType:
        buf = encode_frame(ftype, 42, b"payload")
        assert is_frame(buf)
        frame, end = decode_frame(buf)
        assert frame.ftype == ftype
        assert frame.seq == 42
        assert frame.payload == b"payload"
        assert end == len(buf)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_frame_single_bitflip_never_decodes(seed):
    """Any one flipped bit is caught — CorruptFrame or TruncatedFrame,
    never a silently wrong Frame."""
    rng = np.random.default_rng(seed)
    payload = bytes(rng.integers(0, 256, size=int(rng.integers(1, 64)), dtype=np.uint8))
    buf = bytearray(encode_frame(FrameType.DATA, int(rng.integers(0, 2**32)), payload))
    i = int(rng.integers(0, len(buf)))
    buf[i] ^= 1 << int(rng.integers(0, 8))
    with pytest.raises(wire.WireError):
        decode_frame(bytes(buf))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_frame_truncation_never_decodes(seed):
    rng = np.random.default_rng(seed)
    buf = encode_frame(FrameType.SYNC, 7, bytes(33))
    cut = int(rng.integers(0, len(buf)))
    with pytest.raises(wire.TruncatedFrame):
        decode_frame(buf[:cut])


@settings(max_examples=30, deadline=None)
@given(fault=st.sampled_from(FAULT_CLASSES), seed=st.integers(min_value=0, max_value=999))
def test_link_reliable_under_each_fault_class(fault, seed):
    """Property: under every single fault class at high rate, a Link still
    delivers all payloads, intact and in order."""
    spec = FaultSpec(**{fault: 0.4}, seed=seed)
    link = Link(fault_spec=spec, timeout=4, max_retries=8)
    payloads = [bytes([i]) * (i + 1) for i in range(12)]
    oks = [link.send(p) for p in payloads]
    link.settle()
    assert all(oks)
    assert link.recv() == payloads


def test_wire_error_hierarchy():
    """Transport reuses the wire exception tree; all are ValueError so
    pre-hierarchy callers keep working."""
    assert issubclass(wire.CorruptFrame, wire.WireError)
    assert issubclass(wire.TruncatedFrame, wire.WireError)
    assert issubclass(wire.WireError, ValueError)


# ---------------------------------------------------------------------------
# channels + fault injection
# ---------------------------------------------------------------------------


def test_loopback_channel_orders_by_tick():
    ch = LoopbackChannel()
    ch.send(b"late", delay=3)
    ch.send(b"now")
    assert ch.poll() == [b"now"]
    assert ch.poll() == []
    assert ch.poll() == [b"late"]


def test_fault_injector_deterministic():
    spec = FaultSpec(drop=0.2, corrupt=0.2, duplicate=0.2, reorder=0.2, seed=11)
    plans = []
    for _ in range(2):
        inj = FaultInjector(spec)
        plans.append([inj.plan(bytes(range(32))) for _ in range(200)])
    assert plans[0] == plans[1]


def test_faulty_channel_counts_drops():
    spec = FaultSpec(drop=1.0, seed=0)
    ch = FaultyChannel(LoopbackChannel(), spec)
    for _ in range(5):
        ch.send(b"x")
    assert all(ch.poll() == [] for _ in range(4))
    assert ch.counts["drop"] == 5


# ---------------------------------------------------------------------------
# link recovery behaviour
# ---------------------------------------------------------------------------


def test_link_heavy_faults_in_order_delivery():
    spec = FaultSpec(drop=0.15, corrupt=0.1, truncate=0.05, duplicate=0.1,
                     reorder=0.2, reorder_window=4, straggler=0.05,
                     straggler_ticks=6, seed=1234)
    link = Link(fault_spec=spec, timeout=4, max_retries=8)
    payloads = [f"msg{i}".encode() for i in range(60)]
    assert all(link.send(p) for p in payloads)
    link.settle(16)
    assert link.recv() == payloads
    assert link.stats.retries > 0
    assert link.stats.corrupt_detected + link.stats.truncated_detected > 0


def test_link_pipelined_gap_detection_and_replay():
    """send_nowait keeps frames in flight so a dropped one is noticed as a
    gap by its successor, NAKed, and repaired from the replay ring."""
    spec = FaultSpec(drop=0.2, reorder=0.3, reorder_window=4, seed=7)
    link = Link(fault_spec=spec, timeout=4, max_retries=8, window=64,
                replay_depth=64)
    payloads = [bytes([i % 256]) * 8 for i in range(50)]
    for p in payloads:
        link.send_nowait(p)
    assert link.flush()
    link.settle(16)
    assert link.recv() == payloads
    assert link.stats.gaps_detected > 0


def test_link_delivery_failure_flags_resync():
    link = Link(fault_spec=FaultSpec(drop=1.0, seed=0), timeout=2, max_retries=2)
    assert link.send(b"doomed") is False
    assert link.resync_needed
    assert link.stats.delivery_failures == 1
    assert link.stats.resyncs == 1


def test_sync_frame_repairs_any_gap():
    """A SYNC is self-contained: the receiver accepts it at any forward seq
    and resumes in-sequence delivery right after it."""
    link = Link()
    rx = link.receiver
    rx.on_frame(encode_frame(FrameType.DATA, 0, b"a"))
    # seqs 1..6 lost forever; SYNC jumps the receiver forward
    rx.on_frame(encode_frame(FrameType.SYNC, 7, b"FULL"))
    rx.on_frame(encode_frame(FrameType.DATA, 8, b"b"))
    assert list(rx.delivered) == [b"a", b"FULL", b"b"]
    assert rx.expected == 9


def test_replay_ring_miss_escalates_to_resync():
    """A NAK for a seq already evicted from the bounded replay ring cannot
    be repaired by retransmission — the link must flag resync."""
    link = Link(replay_depth=2)
    for i in range(5):
        link.send_nowait(bytes([i]))
    link.sender.on_control(encode_frame(FrameType.NAK, 0))
    assert link.resync_needed
    assert link.stats.resyncs == 1


def test_duplicates_dropped_once_delivered():
    spec = FaultSpec(duplicate=1.0, seed=3)
    link = Link(fault_spec=spec, timeout=4, max_retries=4)
    payloads = [b"a", b"b", b"c"]
    assert all(link.send(p) for p in payloads)
    link.settle(8)
    assert link.recv() == payloads
    assert link.stats.duplicates_dropped > 0


def test_fleet_seeded_determinism():
    spec = FaultSpec(drop=0.1, corrupt=0.05, reorder=0.1, seed=42)

    def run():
        fleet = Fleet.make(4, spec, timeout=3, max_retries=4)
        for i in range(20):
            fleet.broadcast(bytes([i]) * 16)
        fleet.drain()
        return dataclasses.asdict(fleet.stats())

    assert run() == run()


# ---------------------------------------------------------------------------
# degraded-mode training (the acceptance scenario)
# ---------------------------------------------------------------------------

ACCEPT_SPEC = FaultSpec(drop=0.10, corrupt=0.02, reorder=0.10, reorder_window=4, seed=0)


@pytest.fixture(scope="module")
def prob():
    return problems.generate_problem(n=8, d=64, noise_scale=1.0, seed=0)


def test_marina_p_converges_under_faults(prob):
    """MARINA-P through a degraded fleet (10% drop, 2% corruption, reorder
    window 4) completes, logs nonzero retry/resync counters through
    repro.obs, and reaches the clean run's loss target within 1.5x the
    clean rounds (empirically it matches them exactly: failed deliveries
    roll the affected worker shifts back and the next round is promoted
    to a full sync broadcast)."""
    k = prob.d // prob.n
    p = k / prob.d
    ss = stepsizes.MarinaPPolyak(omega=prob.n - 1, p=p, f_star=prob.f_star)
    clean = marina_p.run(prob, mode="perm", k=k, p=p, stepsize=ss, T=200, seed=1)
    target = 0.25 * clean["f_x"][0]
    r_clean = next(t for t, f in zip(clean["t"], clean["f_x"]) if f < target)

    tracker = obs.MemoryTracker()
    # seed 7: a fault stream whose damage exceeds the tight retry budget
    # early enough that resync promotion fires inside the test horizon
    fleet = Fleet.make(prob.n, ACCEPT_SPEC.with_seed(7), timeout=2, max_retries=1)
    T = int(np.ceil(1.5 * r_clean)) + 5
    h = marina_p.run(prob, mode="perm", k=k, p=p, stepsize=ss, T=T, seed=1,
                     transport=fleet, tracker=tracker)
    r_faulty = next(t for t, f in zip(h["t"], h["f_x"]) if f < target)
    assert r_faulty <= 1.5 * r_clean, (r_faulty, r_clean)

    tr = h["transport"]
    assert tr["transport/retries"] > 0
    assert tr["transport/resyncs"] > 0
    assert 0.0 < tr["transport/goodput"] <= 1.0
    # counters reached the tracker as transport/* metric events
    logged = {}
    for e in tracker.events:
        if e["kind"] == "metrics":
            logged.update(e["metrics"])
    assert logged["transport/retries"] > 0
    assert logged["transport/resyncs"] > 0
    # degradation showed up as forced full-sync rounds, charged dense bits
    assert tr["transport/forced_syncs"] > 0


def test_ef21p_completes_under_faults(prob):
    """EF21-P's two-phase shift commit survives the same fault model: the
    run completes, w/x stay consistent, and re-anchor syncs are counted."""
    alpha = 8 / prob.d
    ss = stepsizes.EF21PPolyak(alpha=alpha, f_star=prob.f_star)
    fleet = Fleet.make(prob.n, ACCEPT_SPEC.with_seed(5), timeout=2, max_retries=1)
    h = ef21p.run(prob, C.TopK(k=8), ss, T=120, transport=fleet)
    assert np.isfinite(h["f_x"]).all()
    assert h["f_x"][-1] < h["f_x"][0]
    tr = h["transport"]
    assert tr["transport/retries"] > 0
    assert tr["transport/delivered_frames"] > 0


def test_marina_p_faulty_matches_clean_when_all_delivered(prob):
    """With no faults the transport path is a pure pass-through: identical
    trajectory to the clean run (same seed, same RNG stream)."""
    k = prob.d // prob.n
    p = k / prob.d
    ss = stepsizes.MarinaPPolyak(omega=prob.n - 1, p=p, f_star=prob.f_star)
    clean = marina_p.run(prob, mode="perm", k=k, p=p, stepsize=ss, T=40, seed=2)
    fleet = Fleet.make(prob.n, None)
    faulty = marina_p.run(prob, mode="perm", k=k, p=p, stepsize=ss, T=40, seed=2,
                          transport=fleet)
    np.testing.assert_allclose(clean["f_x"], faulty["f_x"], rtol=1e-6)
    tr = faulty["transport"]
    assert tr["transport/retries"] == 0
    assert tr["transport/resyncs"] == 0
    assert tr["transport/delivery_failures"] == 0
    # goodput < 1 only by the fixed 16-byte-per-frame framing overhead
    assert tr["transport/goodput"] > 0.8


# ---------------------------------------------------------------------------
# serving endpoint: sequence-gated delta_sync
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine():
    from repro.models import lm
    from repro.models.config import ModelConfig
    from repro.serve import DecodeEngine

    cfg = ModelConfig(arch_id="t", family="gqa", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=64)
    params = lm.lm_init(cfg, jax.random.PRNGKey(0))
    return DecodeEngine(cfg=cfg, params=params, cache_len=16, batch_size=1)


def _flat(params):
    import jax.flatten_util

    return np.asarray(jax.flatten_util.ravel_pytree(params)[0])


def test_delta_sync_sequence_gating(engine):
    flat0 = _flat(engine.params)
    d = flat0.size
    delta = np.zeros(d, np.float32)
    delta[:5] = 0.25
    buf = wire.encode_sparse(delta, mag="fp32")

    f1 = transport.encode_frame(FrameType.DATA, 1, buf)
    engine.delta_sync(f1)
    np.testing.assert_allclose(_flat(engine.params)[:5], flat0[:5] + 0.25, rtol=1e-6)

    with pytest.raises(StaleDelta):  # duplicate delivery must not re-apply
        engine.delta_sync(f1)
    with pytest.raises(SequenceGap):  # skipping a delta would corrupt params
        engine.delta_sync(transport.encode_frame(FrameType.DATA, 3, buf))
    np.testing.assert_allclose(_flat(engine.params)[:5], flat0[:5] + 0.25, rtol=1e-6)

    # a SYNC at any forward seq replaces the params and resets the gate
    engine.delta_sync(
        transport.encode_frame(FrameType.SYNC, 5, wire.encode_dense(flat0, mag="fp32"))
    )
    np.testing.assert_allclose(_flat(engine.params), flat0, atol=0)
    engine.delta_sync(transport.encode_frame(FrameType.DATA, 6, buf))

    # control frames carry no delta
    with pytest.raises(ValueError):
        engine.delta_sync(transport.encode_frame(FrameType.ACK, 7, b""))

    # unframed buffers keep working (pre-transport callers)
    engine.delta_sync(buf)


def test_delta_sync_validates_before_mutating(engine):
    """A payload carrying non-finite values is rejected with the params
    untouched (decode-to-scratch, then swap)."""
    before = _flat(engine.params)
    bad = np.zeros(before.size, np.float32)
    bad[0] = np.inf
    with pytest.raises(wire.CorruptFrame):
        engine.delta_sync(
            transport.encode_frame(
                FrameType.SYNC, 1000, wire.encode_dense(bad, mag="fp32")
            )
        )
    np.testing.assert_array_equal(_flat(engine.params), before)


def test_delta_sync_rejects_damaged_frame(engine):
    before = _flat(engine.params)
    delta = np.zeros(before.size, np.float32)
    buf = bytearray(transport.encode_frame(FrameType.DATA, 2000, wire.encode_sparse(delta)))
    buf[transport.HEADER_BYTES + 2] ^= 0x10
    with pytest.raises(wire.WireError):
        engine.delta_sync(bytes(buf))
    np.testing.assert_array_equal(_flat(engine.params), before)


# ---------------------------------------------------------------------------
# trainer: partial participation + transport threading
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_lm():
    from repro.models.config import ModelConfig

    return ModelConfig(arch_id="t", family="gqa", num_layers=1, d_model=32,
                       num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                       vocab_size=64)


def test_train_loop_degraded_transport(tiny_lm):
    from repro.data import SyntheticLMData
    from repro.optim import make_optimizer
    from repro.optim.schedules import constant_lr
    from repro.train import TrainerConfig, make_downlink, train_loop

    n = 4
    tcfg = TrainerConfig(n_workers=n, remat=False, attn_chunk=32,
                         drop_prob=0.25, straggler_cutoff=2.0)
    dl = make_downlink("marina:perm", n)
    data = SyntheticLMData(tiny_lm, n, 2, 64)
    state, m = train_loop(
        tiny_lm, tcfg, dl, make_optimizer("adamw"), constant_lr(2e-3), data,
        steps=5, key=jax.random.PRNGKey(0),
        transport=ACCEPT_SPEC.with_seed(3),
    )
    assert np.isfinite(float(m["loss"]))
    assert 1 <= float(m["participants"]) <= n
    tr = m["transport"]
    assert tr["transport/delivered_frames"] > 0
    assert 0.0 < tr["transport/goodput"] <= 1.0


def test_trainer_full_participation_unchanged(tiny_lm):
    """drop_prob=0, no transport: the step is bit-identical to before the
    participation/transport features (no participants metric, same RNG)."""
    from repro.data import SyntheticLMData
    from repro.optim import make_optimizer
    from repro.optim.schedules import constant_lr
    from repro.train import TrainerConfig, init_state, make_downlink, make_train_step

    n = 2
    tcfg = TrainerConfig(n_workers=n, remat=False, attn_chunk=32)
    dl = make_downlink("marina:perm", n)
    opt = make_optimizer("adamw")
    state = init_state(tiny_lm, tcfg, dl, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(tiny_lm, tcfg, dl, opt, constant_lr(2e-3)))
    data = SyntheticLMData(tiny_lm, n, 2, 64)
    state, m = step(state, data.batch(0), jax.random.PRNGKey(1))
    assert "participants" not in m
    assert np.isfinite(float(m["loss"]))


def test_downlink_broadcast_via_resync_promotion(tiny_lm):
    """A fleet whose delivery fails reports resync_needed; the next
    broadcast_via(force_sync=True) ships a SYNC that clears it."""
    from repro.models import lm
    from repro.train.downlink import MarinaPDownlink

    dl = MarinaPDownlink(n_workers=2, mode="perm")
    params = lm.lm_init(tiny_lm, jax.random.PRNGKey(0))
    new = jax.tree.map(lambda t: t + 0.01, params)

    fleet = Fleet.make(2, FaultSpec(drop=1.0, seed=0), timeout=1, max_retries=0)
    res = dl.broadcast_via(fleet, jax.random.PRNGKey(1), new, params)
    assert res["resync_needed"]
    assert res["delivered_frac"] == 0.0

    # the links heal: swap the faulty channels for clean ones
    for link in fleet:
        link.data = link.sender.data = LoopbackChannel()
    res = dl.broadcast_via(fleet, jax.random.PRNGKey(2), new, params, force_sync=True)
    assert res["full_sync"] and all(res["oks"])
    assert not res["resync_needed"]
    assert fleet.stats().forced_syncs == 2
