"""Differential harness for the fused on-device encode kernels.

Gates kernels/encode.py against two independent implementations of the wire
format: the host numpy codec (wire/bitstream.py + wire/sparse.py) and the
pure-jnp oracle (kernels/ref.py). The contract is **byte identity** — not
allclose — on every case: packed word streams, whole SPARSE/DENSE messages,
weird IEEE payloads (NaN/±inf/−0.0/denormals, which XLA's FTZ would
silently eat in a float-compare implementation), degenerate shapes, and
the seeded BernK path whose mask must match the SEED codec's receiver-side
rematerialization. The fast tier runs a trimmed fuzz; the ``slow`` marker
carries the full sweep (CI tier1-slow).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import wire
from repro.kernels import encode as kenc
from repro.kernels import ops, ref, runtime

WIDTHS = [1, 4, 7, 8, 13, 16, 32]
MAGS = ["fp32", "fp16", "bf16"]

# every IEEE754 corner the stream extraction must pass through unchanged:
# NaN (payload kept), ±inf, -0.0 (zero magnitude bits => elided like
# np.nonzero), fp32 denormals (FTZ hazard), a bf16-rounding victim, and
# plain normals
WEIRD = np.array(
    [np.nan, np.inf, -np.inf, -0.0, 1e-42, -1e-42, 0.0, 6.1e-39,
     1.0000001, -3.5, 65504.0, 2.0],
    dtype=np.float32,
)


def _sparse_vec(rng, d, density):
    x = rng.standard_normal(d).astype(np.float32)
    return np.where(rng.random(d) < density, x, 0.0).astype(np.float32)


def _bits(a):
    return np.asarray(a, np.float32).view(np.uint32).tolist()


# -- pack level: host vs device kernel vs jnp oracle --------------------------


@settings(max_examples=20, deadline=None)
@given(width=st.sampled_from(WIDTHS), n=st.integers(1, 300),
       seed=st.integers(0, 2**31 - 1))
def test_pack_three_way_differential(width, n, seed):
    """Host packer, Pallas kernel, and jnp oracle emit identical words for
    arbitrary values — including non-word-aligned tails (n free-form)."""
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 1 << width, size=n, dtype=np.uint64).astype(np.uint32)
    host = wire.pack_u32(vals, width)
    oracle = np.asarray(ref.pack_bits_ref(jnp.asarray(vals), width))
    dev = np.asarray(ops.pack_bits(jnp.asarray(vals), width=width))
    assert wire.to_bytes(host) == wire.to_bytes(oracle) == wire.to_bytes(dev)
    # and all three unpackers invert to the same values
    for got in (
        wire.unpack_u32(host, width, n),
        np.asarray(ref.unpack_bits_ref(jnp.asarray(host), width, n)),
        np.asarray(ops.unpack_bits(jnp.asarray(host), width=width, count=n)),
    ):
        np.testing.assert_array_equal(got, vals)


# -- message level: fused pipelines vs host codec -----------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10**6), d=st.sampled_from([1, 5, 33, 100, 257, 512]),
       mag=st.sampled_from(MAGS), dens_pct=st.integers(0, 100))
def test_sparse_encode_differential(seed, d, mag, dens_pct):
    rng = np.random.default_rng(seed)
    x = _sparse_vec(rng, d, dens_pct / 100.0)
    assert kenc.sparse_encode(jnp.asarray(x), mag=mag, block=128) == \
        wire.encode_sparse(x, mag=mag)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10**6), d=st.sampled_from([1, 7, 100, 333]),
       mag=st.sampled_from(MAGS))
def test_dense_encode_differential(seed, d, mag):
    x = np.random.default_rng(seed).standard_normal(d).astype(np.float32)
    assert kenc.dense_encode(jnp.asarray(x), mag=mag, block=128) == \
        wire.encode_dense(x, mag=mag)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10**6), d=st.sampled_from([128, 250, 384]),
       k=st.sampled_from([1, 8, 128, 200]), mag=st.sampled_from(MAGS))
def test_topk_encode_differential(seed, d, k, mag):
    """Fused select+encode == host codec over the standalone TopK kernel —
    including k >= block (selects everything, zeros elided in stream)."""
    x = np.random.default_rng(seed).standard_normal(d).astype(np.float32)
    xj = jnp.asarray(x)
    want = wire.encode_sparse(
        np.asarray(ops.block_topk(xj, k_per_block=k, block=128)), mag=mag)
    assert kenc.topk_encode(xj, k_per_block=k, block=128, mag=mag) == want


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), worker=st.integers(0, 7),
       keep_pct=st.sampled_from([3, 25, 90]), mag=st.sampled_from(MAGS))
def test_mask_encode_differential(seed, worker, keep_pct, mag):
    """Fused BernK mask+encode == host codec over the standalone kernel."""
    d, keep = 384, keep_pct / 100.0
    x = np.random.default_rng(seed % 10**6).standard_normal(d).astype(np.float32)
    xj = jnp.asarray(x)
    want = wire.encode_sparse(
        np.asarray(ops.bernk(xj, keep_prob=keep, seed=seed, worker=worker,
                             block=128)), mag=mag)
    assert kenc.mask_encode(xj, keep_prob=keep, seed=seed, worker=worker,
                            block=128, mag=mag) == want


def test_encode_rows_matches_per_row():
    rng = np.random.default_rng(0)
    X = np.stack([_sparse_vec(rng, 300, 0.1) for _ in range(3)])
    got = kenc.encode_rows(jnp.asarray(X), block=128)
    assert got == [kenc.sparse_encode(jnp.asarray(X[i]), block=128)
                   for i in range(3)]
    assert got == [wire.encode_sparse(X[i]) for i in range(3)]


# -- IEEE edge payloads (byte + decode round-trip agreement) ------------------


@pytest.mark.parametrize("mag", MAGS)
def test_edge_values_sparse(mag):
    buf_host = wire.encode_sparse(WEIRD, mag=mag)
    buf_dev = kenc.sparse_encode(jnp.asarray(WEIRD), mag=mag, block=128)
    assert buf_dev == buf_host
    # decoded values agree bit-for-bit (NaN payloads included)
    assert _bits(wire.decode(buf_dev)) == _bits(wire.decode(buf_host))


@pytest.mark.parametrize("mag", MAGS)
def test_edge_values_dense(mag):
    buf_host = wire.encode_dense(WEIRD, mag=mag)
    buf_dev = kenc.dense_encode(jnp.asarray(WEIRD), mag=mag, block=128)
    assert buf_dev == buf_host
    assert _bits(wire.decode(buf_dev)) == _bits(wire.decode(buf_host))


def test_edge_values_topk():
    """TopK over NaN/inf/denormal payloads: selection and streams match the
    standalone kernel + host codec byte-for-byte."""
    xj = jnp.asarray(WEIRD)
    want = wire.encode_sparse(np.asarray(ops.block_topk(xj, k_per_block=4,
                                                        block=128)))
    assert kenc.topk_encode(xj, k_per_block=4, block=128) == want


def test_all_zero_and_empty_messages():
    z = np.zeros(100, np.float32)
    buf = kenc.sparse_encode(jnp.asarray(z), block=128)
    assert buf == wire.encode_sparse(z)
    np.testing.assert_array_equal(wire.decode(buf), z)
    assert kenc.sparse_encode(jnp.zeros(0, jnp.float32)) == \
        wire.encode_sparse(np.zeros(0, np.float32))


def test_size_one_message():
    for v in (2.5, 0.0, -0.0):
        x = np.array([v], np.float32)
        assert kenc.sparse_encode(jnp.asarray(x)) == wire.encode_sparse(x)
        assert kenc.dense_encode(jnp.asarray(x)) == wire.encode_dense(x)


def test_topk_k_ge_d():
    x = np.random.default_rng(1).standard_normal(96).astype(np.float32)
    xj = jnp.asarray(x)
    want = wire.encode_sparse(np.asarray(ops.block_topk(xj, k_per_block=128,
                                                        block=128)))
    assert kenc.topk_encode(xj, k_per_block=128, block=128) == want


def test_truncated_fused_buffers_raise_typed_errors():
    """Decoding a cut fused buffer fails with the codec's typed errors, not
    garbage output — the device path produces real wire frames."""
    x = _sparse_vec(np.random.default_rng(2), 200, 0.2)
    for buf in (kenc.sparse_encode(jnp.asarray(x), block=128),
                kenc.dense_encode(jnp.asarray(x), block=128)):
        with pytest.raises(wire.TruncatedFrame):
            wire.decode(buf[:-1])
        with pytest.raises(wire.WireError):
            wire.decode(buf[:6])  # inside the common header
        bad = bytearray(buf)
        bad[0] ^= 0xFF  # magic
        with pytest.raises(wire.CorruptFrame):
            wire.decode(bytes(bad))


# -- seeded determinism -------------------------------------------------------


def test_mask_encode_deterministic_across_paths():
    """Same (seed, worker) => identical packed bytes from the scalar path,
    a different block size, explicit interpret, and the vmapped per-worker
    batch — the counter hash is global-index keyed, so layout can't leak
    into the stream."""
    x = np.random.default_rng(3).standard_normal(512).astype(np.float32)
    xj = jnp.asarray(x)
    kw = dict(keep_prob=0.25, seed=42)
    b1 = kenc.mask_encode(xj, worker=3, block=128, **kw)
    assert b1 == kenc.mask_encode(xj, worker=3, block=256, **kw)
    assert b1 == kenc.mask_encode(xj, worker=3, block=128, interpret=True, **kw)
    batch = kenc.encode_per_worker(xj, n_workers=5, mode="ind", block=128, **kw)
    assert batch[3] == b1
    assert len(set(batch)) == 5  # distinct workers => distinct masks
    same = kenc.encode_per_worker(xj, n_workers=4, mode="same", block=128, **kw)
    assert same == [kenc.mask_encode(xj, worker=0, block=128, **kw)] * 4


def test_mask_encode_matches_seed_codec_bern():
    """mask_encode(seed = msg.seed + msg.round) reproduces exactly what a
    SEED-codec receiver rematerializes (wire/seedonly.py BERN family)."""
    delta = np.random.default_rng(4).standard_normal(384).astype(np.float32)
    msg = wire.SeedMessage(family=wire.SeedFamily.BERN, seed=7, round=5,
                           scale=1.0, n=4, worker=2, param=0.25)
    want = wire.apply_seed(msg, delta)
    buf = kenc.mask_encode(jnp.asarray(delta), keep_prob=0.25,
                           seed=msg.seed + msg.round, worker=msg.worker,
                           block=128)
    assert _bits(wire.decode(buf)) == _bits(want)


def test_ind_broadcast_uses_split_not_fold_in():
    """Regression guard for the PR-1 key-derivation fix: ind-mode per-worker
    keys come from jax.random.split, NOT fold_in — the SPMD path
    (core/distributed.py) regenerates the same masks from split keys, so a
    silent revert here would desynchronize server and workers."""
    from repro.core.compressors import RandK
    from repro.core.marina_p import make_broadcast

    n, k, d = 4, 16, 128
    bcast, _ = make_broadcast("ind", n, k)
    key = jax.random.PRNGKey(9)
    delta = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    Q = np.asarray(bcast(key, delta))
    comp = RandK(k=k)
    keys = jax.random.split(key, n)
    want = np.asarray(jax.vmap(lambda kk: comp(kk, delta))(keys))
    np.testing.assert_array_equal(Q, want)
    folded = np.stack([
        np.asarray(comp(jax.random.fold_in(key, i), delta)) for i in range(n)
    ])
    assert not np.array_equal(Q, folded)


# -- interpret / device-encode knobs ------------------------------------------


def test_interpret_env_knob(monkeypatch):
    monkeypatch.setenv(runtime.ENV_VAR, "1")
    assert runtime.default_interpret() is True
    monkeypatch.setenv(runtime.ENV_VAR, "off")
    assert runtime.default_interpret() is False
    monkeypatch.setenv(runtime.ENV_VAR, "auto")
    assert runtime.default_interpret() is (jax.default_backend() != "tpu")
    monkeypatch.delenv(runtime.ENV_VAR, raising=False)
    assert runtime.resolve_interpret(None) == runtime.default_interpret()
    assert runtime.resolve_interpret(True) is True
    assert runtime.resolve_interpret(False) is False


def test_device_encode_env_knob(monkeypatch):
    monkeypatch.setenv(kenc.DEVICE_ENCODE_ENV, "1")
    assert kenc.device_encode_enabled() is True
    monkeypatch.setenv(kenc.DEVICE_ENCODE_ENV, "0")
    assert kenc.device_encode_enabled() is False
    assert kenc.device_encode_enabled(True) is True  # override beats env
    monkeypatch.setenv(kenc.DEVICE_ENCODE_ENV, "auto")
    assert kenc.device_encode_enabled() is (jax.default_backend() == "tpu")


def test_registry_device_fast_path():
    """wire.encode(device_encode=True) on a jax array routes through the
    fused kernels and still emits the host codec's exact bytes; numpy
    inputs silently keep the host path."""
    x = _sparse_vec(np.random.default_rng(5), 200, 0.1)
    xj = jnp.asarray(x)
    assert wire.encode(xj, device_encode=True) == wire.encode(x, device_encode=False)
    from repro.core.compressors import Identity

    assert wire.encode(xj, Identity(), device_encode=True) == \
        wire.encode(x, Identity(), device_encode=False)
    assert wire.encode(x, device_encode=True) == wire.encode(x)  # numpy: host


# -- full fuzz sweep (CI tier1-slow) ------------------------------------------


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       d=st.sampled_from([1, 33, 257, 512, 1000, 2048]),
       mag=st.sampled_from(MAGS), dens_pct=st.integers(0, 100),
       block=st.sampled_from([128, 256, 1024]))
def test_sparse_encode_fuzz_sweep(seed, d, mag, dens_pct, block):
    rng = np.random.default_rng(seed)
    x = _sparse_vec(rng, d, dens_pct / 100.0)
    # sprinkle IEEE corners into live coordinates
    live = np.nonzero(x)[0]
    if live.size:
        x[live[: WEIRD.size]] = WEIRD[: live.size]
    assert kenc.sparse_encode(jnp.asarray(x), mag=mag, block=block) == \
        wire.encode_sparse(x, mag=mag)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), worker=st.integers(0, 31),
       keep_pct=st.integers(1, 99), mag=st.sampled_from(MAGS))
def test_mask_encode_fuzz_sweep(seed, worker, keep_pct, mag):
    d, keep = 1024, keep_pct / 100.0
    x = np.random.default_rng(seed % 10**6).standard_normal(d).astype(np.float32)
    xj = jnp.asarray(x)
    want = wire.encode_sparse(
        np.asarray(ops.bernk(xj, keep_prob=keep, seed=seed, worker=worker)),
        mag=mag)
    assert kenc.mask_encode(xj, keep_prob=keep, seed=seed, worker=worker,
                            mag=mag) == want


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), d=st.sampled_from([512, 1000, 2048]),
       k=st.sampled_from([1, 16, 64, 256, 300]), mag=st.sampled_from(MAGS))
def test_topk_encode_fuzz_sweep(seed, d, k, mag):
    x = np.random.default_rng(seed).standard_normal(d).astype(np.float32)
    xj = jnp.asarray(x)
    want = wire.encode_sparse(
        np.asarray(ops.block_topk(xj, k_per_block=k, block=256)), mag=mag)
    assert kenc.topk_encode(xj, k_per_block=k, block=256, mag=mag) == want


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([2, 5, 8]))
def test_encode_per_worker_fuzz_sweep(seed, n):
    x = np.random.default_rng(seed % 10**6).standard_normal(512).astype(np.float32)
    xj = jnp.asarray(x)
    batch = kenc.encode_per_worker(xj, n_workers=n, keep_prob=0.1, seed=seed,
                                   mode="ind", block=128)
    for w in range(n):
        want = wire.encode_sparse(np.asarray(
            ops.bernk(xj, keep_prob=0.1, seed=seed, worker=w, block=128)))
        assert batch[w] == want
