"""SPMD (shard_map) federated rounds == single-process references.

Runs in a subprocess with 4 forced host devices (jax locks the device count
at first init, so the main test process stays single-device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.core import problems, marina_p, ef21p, distributed, stepsizes, compressors

    prob = problems.generate_problem(n=8, d=64, noise_scale=1.0, seed=1)
    mesh = Mesh(np.array(jax.devices()).reshape(4), ("workers",))
    A = distributed.shard_problem(mesh, prob.A)

    # ---- MARINA-P, all three modes --------------------------------------
    for mode in ("same", "ind", "perm"):
        ss = stepsizes.Constant(gamma=0.05)
        ref_step = jax.jit(marina_p.make_step(prob, mode, k=8, p=0.1, stepsize=ss))
        spmd_step = distributed.make_marina_p_spmd_step(
            mesh, n=8, d=64, mode=mode, k=8, p=0.1, stepsize=ss)
        state = marina_p.init(prob.x0, 8)
        x, W, t = state.x, state.W, state.t
        key = jax.random.PRNGKey(42)
        for i in range(8):
            key, sub = jax.random.split(key)
            state, m1 = ref_step(state, sub)
            x, W, t, m2 = spmd_step(x, W, t, A, sub)
        assert float(jnp.max(jnp.abs(state.x - x))) < 1e-4, mode
        assert float(jnp.max(jnp.abs(state.W - W))) < 1e-4, mode
    print("MARINA-P SPMD OK")

    # ---- EF21-P ----------------------------------------------------------
    # Teacher-forced single-step equivalence. TopK selection can flip on
    # floating-point near-ties (psum reduction order differs between the
    # single-process and SPMD programs), so w_new is compared only when the
    # k-th magnitude gap is resolvable; x_new must always match.
    ss = stepsizes.Constant(gamma=0.05)
    ref_step = jax.jit(ef21p.make_step(prob, compressors.TopK(k=8), ss))
    spmd_step = distributed.make_ef21p_spmd_step(mesh, n=8, d=64, k=8, stepsize=ss)
    key = jax.random.PRNGKey(0)
    checked = 0
    state = ef21p.init(prob.x0)
    for i in range(16):
        key, sub = jax.random.split(key)
        new_state, m1 = ref_step(state, sub)
        x, w, t, m2 = spmd_step(state.x, state.w, state.t, A)
        assert float(jnp.max(jnp.abs(new_state.x - x))) < 1e-4, i
        mags = jnp.sort(jnp.abs(new_state.x - state.w))[::-1]
        if float(mags[7] - mags[8]) > 1e-5:  # selection unambiguous
            assert float(jnp.max(jnp.abs(new_state.w - w))) < 1e-4, i
            checked += 1
        state = new_state  # teacher-force the reference trajectory
    # the tridiagonal A_i make exact magnitude ties common; require that at
    # least a few rounds were unambiguous and all of those matched exactly
    assert checked >= 2, checked
    print("EF21-P SPMD OK")
    """
)


@pytest.mark.slow
def test_spmd_equivalence_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    # force CPU: the host-device count flag needs the cpu platform, and
    # letting jax probe for a TPU burns ~90s of init timeouts per run
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True, timeout=600
    )
    assert res.returncode == 0, res.stdout + res.stderr
    assert "MARINA-P SPMD OK" in res.stdout
    assert "EF21-P SPMD OK" in res.stdout
