"""repro.fleet: populations, samplers, cohort runs, participation plans."""
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import ef21p, marina_p, problems, stepsizes
from repro.core.compressors import TopK
from repro.data import SyntheticLMData
from repro.fleet import (
    AvailabilityWindowPlan,
    BernoulliStragglerPlan,
    CyclingMaskPlan,
    FleetL1Problem,
    FullParticipation,
    fleet_run,
    make_fleet,
    make_sampler,
    plan_from_legacy,
)
from repro.optim import make_optimizer
from repro.optim.schedules import constant_lr
from repro.train import TrainerConfig, init_state, make_downlink, make_train_step


# ---------------------------------------------------------------------------
# population
# ---------------------------------------------------------------------------


def test_population_attributes_deterministic_and_stateless():
    spec = make_fleet("two_tier", 1_000_000, seed=11)
    ids = np.asarray([0, 17, 999_999, 123_456])
    assert (spec.tier_index(ids) == spec.tier_index(ids)).all()
    assert np.allclose(spec.data_size(ids), spec.data_size(ids))
    # order/batching must not matter (pure per-id hashing)
    one_by_one = np.concatenate([spec.data_size(np.asarray([i])) for i in ids])
    assert np.allclose(spec.data_size(ids), one_by_one)
    # different seeds decorrelate
    other = make_fleet("two_tier", 1_000_000, seed=12)
    assert not (spec.tier_index(np.arange(200)) == other.tier_index(np.arange(200))).all()


def test_tier_fractions_match_weights():
    spec = make_fleet("two_tier", 4096, seed=0)
    frac_dc = spec.tier_index(np.arange(4096)).mean()  # tier 1 = "dc", weight 0.3
    assert abs(frac_dc - 0.3) < 0.05


def test_availability_trace_duty_cycle():
    spec = make_fleet("two_tier_diurnal", 2048, seed=0)
    ids = np.arange(2048)
    open_frac = np.mean([spec.available(ids, t).mean() for t in range(24)])
    assert abs(open_frac - 0.5) < 0.05
    # each client's own window is exactly duty * period ticks long
    avail_t = np.stack([spec.available(ids[:32], t) for t in range(24)])
    assert (avail_t.sum(axis=0) == spec.availability.open_ticks).all()


def test_fault_spec_plugs_into_transport():
    from repro.transport import FaultInjector, FaultSpec

    spec = make_fleet("flaky_mobile", 10_000, seed=2)
    fs = spec.fault_spec_for(1234, round_salt=5)
    assert isinstance(fs, FaultSpec) and fs.any_faults
    assert fs == spec.fault_spec_for(1234, round_salt=5)  # deterministic
    assert fs != spec.fault_spec_for(1234, round_salt=6)  # fresh stream per round
    plans = FaultInjector(fs).plan(b"\x00" * 16)
    assert isinstance(plans, list)
    # clean mix has no faults at all
    clean = make_fleet("uniform", 100, seed=0).fault_spec_for(7)
    assert not clean.any_faults


def test_fleet_problem_analytic_eigs_match_numpy():
    spec = make_fleet("two_tier", 50_000, seed=4)
    prob = FleetL1Problem(spec, d=12)
    ids = np.asarray([3, 999, 42_000])
    A = prob.materialize(ids)
    L_analytic = prob.client_L0(ids)
    L_numpy = np.asarray([np.abs(np.linalg.eigvalsh(a)).max() for a in A])
    assert np.allclose(L_analytic, L_numpy, rtol=1e-10)
    assert prob.f_star == 0.0 and prob.R0_sq > 0


# ---------------------------------------------------------------------------
# cohort samplers
# ---------------------------------------------------------------------------


def test_samplers_deterministic_and_distinct_per_round():
    spec = make_fleet("uniform", 100_000, seed=0)
    for kind in ("uniform", "weighted", "availability", "deadline:2.0"):
        s = make_sampler(kind, spec, 16, seed=9)
        a, b = s.cohort(3), s.cohort(3)
        assert (a.ids == b.ids).all() and (a.active == b.active).all(), kind
        c = s.cohort(4)
        assert not (a.ids == c.ids).all(), kind  # fresh draw each round
        act = a.weights[a.active]
        if a.n_active:
            assert np.isclose(a.weights.sum(), 1.0) and (act > 0).all()
        assert (a.weights[~a.active] == 0).all()


def test_size_weighted_sampler_biases_toward_large_clients():
    spec = make_fleet("two_tier", 20_000, seed=1)  # dc tier: 4x median size
    s = make_sampler("weighted", spec, 64, seed=0)
    picked = np.concatenate([s.cohort(t).ids[s.cohort(t).active] for t in range(20)])
    frac_dc = (spec.tier_index(picked) == 1).mean()
    assert frac_dc > 0.45  # population fraction is 0.30; size-weighting lifts it


def test_availability_sampler_respects_windows():
    spec = make_fleet("two_tier_diurnal", 8192, seed=3)
    s = make_sampler("availability", spec, 32, seed=0)
    for t in (0, 7, 13):
        co = s.cohort(t)
        assert spec.available(co.ids[co.active], t).all()


def test_deadline_sampler_deactivates_stragglers():
    spec = make_fleet("two_tier_diurnal", 8192, seed=3)  # latency_sigma 0.6
    s = make_sampler("deadline:1.0", spec, 64, seed=0)
    co = s.cohort(0)
    assert 0 < co.n_active < 64  # median latency 1.0 => roughly half miss
    assert (spec.latency(co.ids, 0)[co.active] <= 1.0).all()


def test_cohort_memory_bounded_by_cohort_not_population():
    """A 100k-client population must never materialize population-sized
    state: one 64-client round stays under a few MB of host allocations
    (the [population, d, d] tensor alone would be ~200 MB)."""
    spec = make_fleet("two_tier_diurnal", 100_000, seed=0)
    prob = FleetL1Problem(spec, d=16)
    sampler = make_sampler("uniform", spec, 64, seed=0)
    fleet_run(prob, sampler, stepsizes.Constant(gamma=0.05), T=1, seed=0)  # warm up jit
    tracemalloc.start()
    fleet_run(prob, sampler, stepsizes.Constant(gamma=0.05), T=3, seed=0)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < 32 * 1024 * 1024, f"peak host alloc {peak/1e6:.1f} MB"


# ---------------------------------------------------------------------------
# fleet_run
# ---------------------------------------------------------------------------


def test_fleet_run_marina_converges_and_is_deterministic():
    spec = make_fleet("two_tier", 4096, seed=0)
    prob = FleetL1Problem(spec, d=32)
    sampler = make_sampler("uniform", spec, 8, seed=1)
    h1 = fleet_run(prob, sampler, stepsizes.Constant(gamma=0.05),
                   algorithm="marina_p", mode="perm", T=60, target=None, seed=0)
    h2 = fleet_run(prob, sampler, stepsizes.Constant(gamma=0.05),
                   algorithm="marina_p", mode="perm", T=60, target=None, seed=0)
    assert h1["f_x"] == h2["f_x"]
    assert h1["f_x"][-1] < 0.5 * h1["f_x"][0]
    assert h1["s2w_bits_total"] > 0 and h1["w2s_bits_total"] > 0
    assert h1["participation"].unique_clients <= 60 * 8


def test_fleet_run_ef21p_converges_with_polyak():
    spec = make_fleet("uniform", 2048, seed=0)
    prob = FleetL1Problem(spec, d=32)
    sampler = make_sampler("uniform", spec, 8, seed=1)
    h = fleet_run(prob, sampler, stepsizes.EF21PPolyak(alpha=4 / 32),
                  algorithm="ef21p", k=4, T=80, target=None, seed=0)
    assert np.isfinite(h["f_x"]).all()
    assert h["f_x"][-1] < 0.7 * h["f_x"][0]


def test_fleet_run_rounds_to_target_ceiling():
    spec = make_fleet("uniform", 512, seed=0)
    prob = FleetL1Problem(spec, d=16)
    sampler = make_sampler("uniform", spec, 4, seed=0)
    h = fleet_run(prob, sampler, stepsizes.Constant(gamma=1e-9), T=5, target=1e-12)
    assert h["rounds_to_target"] == 5  # never reached -> T, not NaN/None


def test_fleet_run_faults_degrade_but_stay_finite():
    spec = make_fleet("flaky_mobile", 4096, seed=7)
    prob = FleetL1Problem(spec, d=16)
    sampler = make_sampler("uniform", spec, 8, seed=2)
    h = fleet_run(prob, sampler, stepsizes.Constant(gamma=0.05),
                  algorithm="marina_p", T=40, seed=0)
    stats = h["participation"]
    assert stats.goodput < 1.0  # some frames dropped
    assert stats.fresh_frac > 0  # dropped clients resync on return
    assert np.isfinite(h["f_x"]).all()
    assert h["f_x"][-1] < h["f_x"][0]


def test_fleet_run_wire_measurement_close_to_analytic():
    spec = make_fleet("uniform", 1024, seed=0)
    prob = FleetL1Problem(spec, d=64)
    sampler = make_sampler("uniform", spec, 8, seed=1)
    h = fleet_run(prob, sampler, stepsizes.Constant(gamma=0.05),
                  algorithm="marina_p", mode="perm", T=20, measure_wire=True)
    # fp32 wire vs 64-bit analytic model: same order of magnitude
    assert 0 < h["wire_bits_total"] < h["s2w_bits_total"]


# ---------------------------------------------------------------------------
# ParticipationPlan in core runs (Polyak safety under partial participation)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_prob():
    return problems.generate_problem(n=4, d=16, noise_scale=1.0, seed=0)


def test_full_plan_bit_identical_to_no_plan(small_prob):
    kw = dict(mode="perm", k=4, p=0.25, stepsize=stepsizes.Constant(gamma=0.05),
              T=25, seed=1)
    h0 = marina_p.run(small_prob, **kw)
    h1 = marina_p.run(small_prob, participation=FullParticipation(), **kw)
    assert h0["f_x"] == h1["f_x"]
    assert (np.asarray(h0["final_state"].x) == np.asarray(h1["final_state"].x)).all()


@pytest.mark.parametrize("alg", ["marina_p", "ef21p"])
def test_polyak_finite_on_empty_and_singleton_cohorts(small_prob, alg):
    """EF21PPolyak / MarinaPPolyak aux path: an empty round must give
    gamma = 0 (iterate holds still), a size-1 round a finite positive step."""
    n = small_prob.n
    plan = CyclingMaskPlan(masks=(
        (False,) * n,                       # t = 0: empty
        (True,) + (False,) * (n - 1),       # t = 1: singleton
        (True,) * n,                        # t = 2: full
    ))
    if alg == "marina_p":
        ss = stepsizes.MarinaPPolyak(omega=3.0, p=0.25, f_star=0.0)
        h = marina_p.run(small_prob, mode="perm", k=4, p=0.25, stepsize=ss,
                         T=30, seed=1, participation=plan)
        x0 = small_prob.x0
    else:
        ss = stepsizes.EF21PPolyak(alpha=0.25, f_star=0.0)
        h = ef21p.run(small_prob, TopK(k=4), ss, T=30, seed=1, participation=plan)
        x0 = small_prob.x0
    assert np.isfinite(h["f_x"]).all() and np.isfinite(h["gamma"]).all()
    assert h["participants"][:3] == [0.0, 1.0, float(n)]
    # empty round: gamma = 0 and x unchanged (f_x[0] = f(x0))
    assert h["gamma"][0] == 0.0
    assert np.isclose(h["f_x"][0], float(small_prob.f(jnp.asarray(x0))), rtol=1e-6)
    # singleton round: monotone-safe — finite, non-negative step
    assert h["gamma"][1] >= 0.0 and np.isfinite(h["gamma"][1])


def test_plan_participants_recorded(small_prob):
    h = marina_p.run(small_prob, mode="ind", k=4, p=0.25,
                     stepsize=stepsizes.Constant(gamma=0.05), T=20, seed=1,
                     participation=BernoulliStragglerPlan(drop_prob=0.3))
    assert "participants" in h
    assert min(h["participants"]) >= 0 and max(h["participants"]) <= small_prob.n
    assert min(h["participants"]) < small_prob.n  # drops actually happen


# ---------------------------------------------------------------------------
# trainer: plan hook + legacy shim bit-identity (§8.5 key discipline)
# ---------------------------------------------------------------------------


def _train(tcfg, steps=4):
    cfg = configs.get_smoke("gemma-2b")
    dl = make_downlink("marina:perm", tcfg.n_workers)
    opt = make_optimizer("sgd")
    state = init_state(cfg, tcfg, dl, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg, dl, opt, constant_lr(2e-3)))
    data = SyntheticLMData(cfg, tcfg.n_workers, 2, 64)
    losses = []
    for i in range(steps):
        state, m = step(state, data.batch(i), jax.random.fold_in(jax.random.PRNGKey(9), i))
        losses.append(float(m["loss"]))
    return state, losses, m


def test_trainer_legacy_knobs_bit_identical_to_plan():
    """Identical seeds must give identical cohorts — and therefore
    bit-identical trajectories — via the legacy shim or the explicit plan."""
    legacy = TrainerConfig(n_workers=2, attn_chunk=32, drop_prob=0.4)
    plan = TrainerConfig(n_workers=2, attn_chunk=32,
                         participation=BernoulliStragglerPlan(drop_prob=0.4))
    s1, l1, m1 = _train(legacy)
    s2, l2, m2 = _train(plan)
    assert l1 == l2
    for a, b in zip(jax.tree.leaves(s1["server"]), jax.tree.leaves(s2["server"])):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert float(m1["participants"]) == float(m2["participants"])


def test_trainer_availability_plan_smoke():
    tcfg = TrainerConfig(n_workers=2, attn_chunk=32,
                         participation=AvailabilityWindowPlan(
                             phases=(0, 12), period=24, open_ticks=12))
    _, losses, m = _train(tcfg)
    assert np.isfinite(losses).all()
    assert float(m["participants"]) == 1.0  # anti-phased: one worker per round


def test_trainer_conflicting_participation_config_raises():
    cfg = configs.get_smoke("gemma-2b")
    tcfg = TrainerConfig(n_workers=2, drop_prob=0.1,
                         participation=BernoulliStragglerPlan(drop_prob=0.1))
    with pytest.raises(ValueError, match="mutually exclusive"):
        make_train_step(cfg, tcfg, None, make_optimizer("sgd"), constant_lr(1e-2))


def test_plan_from_legacy_mapping():
    assert plan_from_legacy(0.0, 0.0).is_full
    p = plan_from_legacy(0.2, 1.5)
    assert isinstance(p, BernoulliStragglerPlan)
    assert p.drop_prob == 0.2 and p.straggler_cutoff == 1.5 and not p.is_full
