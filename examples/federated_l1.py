"""Full paper-experiment driver (Figure 1 / Figure 7 reproduction).

Runs EF21-P(TopK) and MARINA-P(sameRandK / indRandK / PermK) under constant
and Polyak stepsizes for every (n, noise-scale) cell, with the paper's
bit-accounting, and writes a CSV of convergence traces.

Run (reduced):  PYTHONPATH=src python examples/federated_l1.py
Paper scale:    PYTHONPATH=src python examples/federated_l1.py --paper
"""
import argparse
import csv
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.fig1_convergence import run_suite  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="d=1000, n in {10,100}")
    ap.add_argument("--out", default="runs/federated_l1.csv")
    args = ap.parse_args()

    if args.paper:
        cells = [(1000, 10, s, 3.5e8) for s in (0.1, 1.0, 10.0)] + [
            (1000, 100, s, 3.5e7) for s in (0.1, 1.0, 10.0)
        ]
    else:
        cells = [(200, 10, s, 4e6) for s in (0.1, 1.0, 10.0)]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["d", "n", "noise", "method", "final_subopt", "rounds", "bits_per_worker"])
        for d, n, s, budget in cells:
            res = run_suite(d=d, n=n, noise=s, budget_bits=budget)
            for name, r in res.items():
                w.writerow([d, n, s, name, r["final_subopt"], r["rounds"], r["bits_per_worker"]])
                print(f"d={d} n={n:3d} s={s:5.1f} {name:22s} "
                      f"f-f*={r['final_subopt']:.4f} rounds={r['rounds']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
