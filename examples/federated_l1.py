"""Full paper-experiment driver (Figure 1 / Figure 7 reproduction).

Runs EF21-P(TopK) and MARINA-P(sameRandK / indRandK / PermK) under constant
and Polyak stepsizes for every (n, noise-scale) cell, with the paper's
bit-accounting, and writes a CSV of convergence traces.

Run (reduced):  PYTHONPATH=src python examples/federated_l1.py
Paper scale:    PYTHONPATH=src python examples/federated_l1.py --paper
Client zoo:     PYTHONPATH=src python examples/federated_l1.py --fleet

``--fleet`` swaps the fixed worker list for a heterogeneous client
population (repro.fleet, DESIGN.md §9): two data tiers — 70% low-noise
"edge" clients and 30% high-noise "dc" clients with 4x the data — behind
a 50%-duty diurnal availability trace. Each round an availability-window
sampler draws a small cohort from the population, so the run prices
join syncs and partial participation the way a real cross-device
deployment would.
"""
import argparse
import csv
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.fig1_convergence import run_suite  # noqa: E402


def fleet_demo(out_path: str, *, population: int, cohort: int, d: int, T: int):
    """Heterogeneous client mix through the fleet API (two tiers +
    availability trace), MARINA-P vs EF21-P under constant and Polyak."""
    from repro.core import stepsizes
    from repro.fleet import FleetL1Problem, fleet_run, make_fleet, make_sampler

    spec = make_fleet("two_tier_diurnal", population, seed=0)
    prob = FleetL1Problem(spec, d=d)
    sampler = make_sampler("availability", spec, cohort, seed=0)
    k = max(1, d // cohort)
    runs = {
        "marina_p_perm_const": dict(
            algorithm="marina_p",
            stepsize=stepsizes.Constant(gamma=0.05)),
        "marina_p_perm_polyak": dict(
            algorithm="marina_p",
            stepsize=stepsizes.MarinaPPolyak(omega=float(cohort - 1), p=k / d)),
        "ef21p_topk_polyak": dict(
            algorithm="ef21p",
            stepsize=stepsizes.EF21PPolyak(alpha=k / d)),
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["method", "final_f", "rounds", "s2w_bits", "join_bits",
                    "participants_mean", "unique_clients", "fresh_frac", "goodput"])
        for name, kw in runs.items():
            h = fleet_run(prob, sampler, kw["stepsize"], algorithm=kw["algorithm"],
                          mode="perm", k=k, T=T, seed=0)
            st = h["participation"]
            row = [name, h["f_x"][-1], T, h["s2w_bits_total"], h["join_bits_total"],
                   st.participant_rounds / max(st.rounds, 1), st.unique_clients,
                   st.fresh_frac, st.goodput]
            w.writerow(row)
            print(f"{name:22s} f={h['f_x'][-1]:8.4f} "
                  f"s2w={h['s2w_bits_total']:.3g}b join={h['join_bits_total']:.3g}b "
                  f"cohort~{row[5]:.1f} clients={st.unique_clients} "
                  f"fresh={st.fresh_frac:.2f}")
    print(f"wrote {out_path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="d=1000, n in {10,100}")
    ap.add_argument("--fleet", action="store_true",
                    help="heterogeneous client-zoo demo (two tiers + diurnal windows)")
    ap.add_argument("--population", type=int, default=50_000)
    ap.add_argument("--cohort", type=int, default=16)
    ap.add_argument("-T", type=int, default=200)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.fleet:
        fleet_demo(args.out or "runs/federated_l1_fleet.csv",
                   population=args.population, cohort=args.cohort,
                   d=128, T=args.T)
        return

    out = args.out or "runs/federated_l1.csv"
    if args.paper:
        cells = [(1000, 10, s, 3.5e8) for s in (0.1, 1.0, 10.0)] + [
            (1000, 100, s, 3.5e7) for s in (0.1, 1.0, 10.0)
        ]
    else:
        cells = [(200, 10, s, 4e6) for s in (0.1, 1.0, 10.0)]

    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["d", "n", "noise", "method", "final_subopt", "rounds", "bits_per_worker"])
        for d, n, s, budget in cells:
            res = run_suite(d=d, n=n, noise=s, budget_bits=budget)
            for name, r in res.items():
                w.writerow([d, n, s, name, r["final_subopt"], r["rounds"], r["bits_per_worker"]])
                print(f"d={d} n={n:3d} s={s:5.1f} {name:22s} "
                      f"f-f*={r['final_subopt']:.4f} rounds={r['rounds']}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
