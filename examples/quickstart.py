"""Quickstart: 60 seconds with the library.

1. Build the paper's synthetic non-smooth problem (Algorithm 3).
2. Run MARINA-P with PermK + Polyak stepsize (the paper's winner).
3. Compare against EF21-P(TopK) and plain SM at the same downlink budget.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import compressors as C
from repro.core import ef21p, marina_p, problems, stepsizes, subgradient

prob = problems.generate_problem(n=10, d=200, noise_scale=1.0, seed=0)
print(f"problem: n={prob.n} d={prob.d} sigma_A={prob.sigma_A:.3f} f(x0)={float(prob.f(prob.x0)):.2f}")

k = prob.d // prob.n          # K = d/n (paper §5)
p = k / prob.d                # p = K/d
BUDGET = 2e6                  # downlink bits per worker

# --- MARINA-P + PermK + Polyak (23) -------------------------------------------
h_m = marina_p.run(
    prob, mode="perm", k=k, p=p,
    stepsize=stepsizes.MarinaPPolyak(omega=prob.n - 1, p=p, f_star=0.0),
    bit_budget=BUDGET,
)
# --- EF21-P + TopK + Polyak (13) ----------------------------------------------
h_e = ef21p.run(
    prob, C.TopK(k=k),
    stepsizes.EF21PPolyak(alpha=k / prob.d, f_star=0.0),
    bit_budget=BUDGET,
)
# --- uncompressed subgradient method (eq. 5) ----------------------------------
h_s = subgradient.run(prob, stepsizes.Constant(5e-3), bit_budget=BUDGET)

for name, h in [("MARINA-P/PermK/Polyak", h_m), ("EF21-P/TopK/Polyak", h_e), ("SM (dense)", h_s)]:
    print(f"{name:24s} rounds={h['ledger'].rounds:5d} "
          f"bits/worker={h['ledger'].s2w_bits:.2e} final f-f*={h['f_x'][-1]:.4f}")
