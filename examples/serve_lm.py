"""Batched serving demo: prefill + generate over the decode engine.

Loads a checkpoint if present (e.g. from examples/train_lm.py), otherwise
random-initializes, then serves a batch of prompts with greedy and sampled
decoding.

Run:  PYTHONPATH=src python examples/serve_lm.py --batch 4 --new-tokens 32
"""
import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import load_checkpoint
from repro.models import lm
from repro.serve import DecodeEngine, greedy_sample, temperature_sample

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from train_lm import model_100m  # noqa: E402 (same directory)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default="runs/train_lm_ckpt.npz")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--temp", type=float, default=0.0, help="0 = greedy")
    args = ap.parse_args()

    cfg = model_100m(args.layers, args.d_model)
    params = lm.lm_init(cfg, jax.random.PRNGKey(0))
    if os.path.exists(args.ckpt):
        params, meta = load_checkpoint(args.ckpt, params)
        print(f"loaded {args.ckpt} (step {meta['step']})")
    else:
        print("no checkpoint found — serving random init")

    engine = DecodeEngine(
        cfg, params,
        cache_len=args.prompt_len + args.new_tokens,
        batch_size=args.batch,
        sample_fn=temperature_sample(args.temp) if args.temp > 0 else greedy_sample,
    )
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    toks = engine.run(prompts, n_new_tokens=args.new_tokens)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"generated {total} tokens in {dt:.1f}s ({total/dt:.1f} tok/s, batch={args.batch})")
    for b in range(min(args.batch, 2)):
        print(f"  req{b}: {list(map(int, toks[b][:16]))} ...")


if __name__ == "__main__":
    main()
