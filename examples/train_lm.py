"""End-to-end LM training with MARINA-P downlink compression.

Trains a ~100M-parameter gemma-family model for a few hundred steps on the
synthetic token pipeline, with the paper's compressed server->worker model
broadcast as a first-class feature, and checkpoints at the end.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults are sized for the CPU container; --steps 300 takes a while — use
--steps 30 for a smoke run.)
"""
import argparse
import time

import jax

from repro.data import SyntheticLMData
from repro.checkpoint import save_checkpoint
from repro.models.config import ModelConfig, uniform_pattern
from repro.optim import make_optimizer
from repro.optim.schedules import cosine_warmup
from repro.train import TrainerConfig, init_state, make_downlink, make_train_step


def model_100m(layers=8, d_model=768):
    """~100M params, gemma-flavoured (GeGLU, MQA)."""
    return ModelConfig(
        arch_id="demo-100m", family="dense", num_layers=layers, d_model=d_model,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32768,
        block_pattern=uniform_pattern("attn", layers), mlp_kind="geglu",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch-per-worker", type=int, default=2)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--downlink", default="marina:perm",
                    help="marina:perm|marina:ind|marina:same|ef21p:128:1024|none")
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--ckpt", default="runs/train_lm_ckpt.npz")
    args = ap.parse_args()

    cfg = model_100m(args.layers, args.d_model)
    from repro.models import lm
    print(f"model: {lm.count_params(cfg)/1e6:.1f}M params, downlink={args.downlink}")

    tcfg = TrainerConfig(n_workers=args.workers, attn_chunk=128)
    downlink = make_downlink(args.downlink, args.workers)
    optimizer = make_optimizer("adamw", weight_decay=0.01)
    lr = cosine_warmup(3e-4, warmup=min(50, args.steps // 4), total=args.steps)
    state = init_state(cfg, tcfg, downlink, optimizer, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg, downlink, optimizer, lr), donate_argnums=0)
    data = SyntheticLMData(cfg, args.workers, args.batch_per_worker, args.seq)

    t0 = time.time()
    for i in range(args.steps):
        state, m = step(state, data.batch(i), jax.random.fold_in(jax.random.PRNGKey(7), i))
        if i % 10 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:4d} loss={float(m['loss']):.4f} lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f} drift={float(m.get('drift', 0)):.3e} "
                  f"bits/w={float(m['bits_per_worker']):.2e} ({dt:.0f}s)")
    save_checkpoint(args.ckpt, state["server"], step=args.steps,
                    extra={"arch": cfg.arch_id, "downlink": args.downlink})
    print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
