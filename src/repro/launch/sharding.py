"""Sharding rules: parameter/batch/cache PartitionSpecs (DESIGN.md §5).

Rules are name-based over tree paths and validated against actual leaf
shapes (an axis is only sharded if its size divides the mesh axis size —
otherwise it is left replicated, e.g. 36 q-heads never shard but the
flattened 4608 projection dim does).

Parameter layouts:
* ``worker``  — leading W axis over the worker mesh axes (= ("pod","data")
                flattened); inner dims over "model" (MARINA-P per-worker
                replicas; classic DP memory footprint).
* ``server``  — fp32 master + optimizer moments: ZeRO-1-style, sharded over
                the data axes (fsdp) AND "model" where divisible.
* ``serve``   — inference params: fsdp over (data axes, "model") jointly.
"""
from __future__ import annotations

import math
from typing import Any, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# names whose last dim shards over "model" (column-parallel)
_COL = {
    "wq", "wk", "wv", "w_in", "w_gate", "w_r", "w_k", "w_v", "w_g",
    "cm_k", "cm_r", "in_proj", "w_dkv", "w_krope", "w_uk", "w_uv",
    "w_lora_a", "w_lora_b", "router", "conv_w", "unembed",
}
# names whose second-to-last dim shards over "model" (row-parallel)
_ROW = {"wo", "w_out", "out_proj", "cm_v", "embed"}
# always replicated (small vectors / scalars)
_REP = {"scale", "w0", "u", "A_log", "D", "dt_bias", "conv_b", "mu", "cm_mu", "count"}


def dp_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    if "wk" in mesh.axis_names:  # hierarchical (§Perf C4)
        return ("wk", "data")
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def worker_axis_of(mesh: Mesh):
    if "wk" in mesh.axis_names:
        return "wk"
    dp = dp_axes_of(mesh)
    return dp[0] if len(dp) == 1 else dp


def worker_fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that FSDP-shard *inside* one worker's replica (hierarchical only)."""
    return ("data",) if "wk" in mesh.axis_names else ()


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _base_spec(path_str: str, shape: Tuple[int, ...], model_size: int) -> list:
    """Per-leaf spec (list of axis names / None), 'model' placements only."""
    name = path_str.split("/")[-1]
    spec: list = [None] * len(shape)
    if name in _REP or not shape:
        return spec

    def try_shard(dim_idx):
        if shape[dim_idx] % model_size == 0 and shape[dim_idx] >= model_size:
            spec[dim_idx] = "model"

    is_expert = ("moe" in path_str and "shared" not in path_str
                 and name in ("w_in", "w_gate", "w_out") and len(shape) >= 3)
    if is_expert:
        try_shard(-3)  # expert-parallel on E
    elif name in _ROW:
        try_shard(-2 if len(shape) >= 2 else -1)
    elif name in _COL:
        try_shard(-1)
    return spec


def _add_fsdp(spec: list, shape, dp: Tuple[str, ...], dp_size: int) -> list:
    """Shard the largest still-replicated dim over the data axes (ZeRO/fsdp)."""
    best, best_size = None, 0
    for i, (s, sp) in enumerate(zip(shape, spec)):
        if sp is None and s % dp_size == 0 and s > best_size and s >= dp_size:
            best, best_size = i, s
    if best is not None:
        spec = list(spec)
        spec[best] = dp[0] if len(dp) == 1 else dp
    return spec


def param_specs(params_shape, mesh: Mesh, layout: str):
    """Pytree of PartitionSpec matching ``params_shape`` (eval_shape output)."""
    model_size = mesh.shape["model"]
    dp = dp_axes_of(mesh)
    dp_size = _axes_size(mesh, dp)
    w_ax = worker_axis_of(mesh)
    w_fsdp = worker_fsdp_axes(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if layout == "replicated":
            return P()
        if layout == "worker":
            inner = _base_spec(ps, shape[1:], model_size)
            if w_fsdp:  # hierarchical: FSDP the replica inside the group
                inner = _add_fsdp(inner, shape[1:], w_fsdp, _axes_size(mesh, w_fsdp))
            return P(w_ax, *inner)
        name = ps.split("/")[-1]
        if layout == "tp_attn_rep" and name in ("wq", "wk", "wv", "wo"):
            # batch-parallel attention: replicate attention projections so the
            # (head-count % model_size != 0) reshape never gathers activations
            return P(*([None] * len(shape)))
        spec = _base_spec(ps, shape, model_size)
        if layout not in ("tp", "tp_attn_rep"):  # "tp*": no ZeRO-3 gathers
            spec = _add_fsdp(spec, shape, dp, dp_size)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_specs(cache_shape, mesh: Mesh, batch: int):
    """KV/SSM cache specs: batch dim over the data axes when divisible,
    else the longest divisible dim (sequence, for long_500k B=1)."""
    dp = dp_axes_of(mesh)
    dp_size = _axes_size(mesh, dp)
    ax = dp[0] if len(dp) == 1 else dp

    def one(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        bdim = None
        for i, s in enumerate(shape[:2]):
            if s == batch:
                bdim = i
                break
        if bdim is not None and batch % dp_size == 0 and batch >= dp_size:
            spec[bdim] = ax
        else:
            cand = [(s, i) for i, s in enumerate(shape) if s % dp_size == 0 and s >= dp_size]
            if cand:
                _, i = max(cand)
                spec[i] = ax
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def train_batch_spec(batch_shape, mesh: Mesh):
    """Training batch [W, B_local, ...]: W over the worker axes; in the
    hierarchical mesh the within-worker batch also shards over 'data'."""
    ax = worker_axis_of(mesh)
    w_fsdp = worker_fsdp_axes(mesh)

    def one(leaf):
        spec = [None] * (len(leaf.shape) - 1)
        if w_fsdp and len(leaf.shape) >= 2 and leaf.shape[1] % _axes_size(mesh, w_fsdp) == 0:
            spec[0] = w_fsdp[0]
        return P(ax, *spec)

    return jax.tree.map(one, batch_shape)


def serve_batch_spec(batch_shape, mesh: Mesh, batch: int):
    dp = dp_axes_of(mesh)
    dp_size = _axes_size(mesh, dp)
    ax = dp[0] if len(dp) == 1 else dp

    def one(leaf):
        spec = [None] * len(leaf.shape)
        if leaf.shape and leaf.shape[0] == batch and batch % dp_size == 0 and batch >= dp_size:
            spec[0] = ax
        return P(*spec)

    return jax.tree.map(one, batch_shape)


def scalar_specs(tree_shape):
    return jax.tree.map(lambda _: P(), tree_shape)
