"""Per-(architecture × input-shape × mesh) lowering specs.

``build(arch_id, shape_id, mesh)`` returns the step function, its abstract
arguments (ShapeDtypeStructs — no allocation), and matching in_shardings.

Input shapes (assigned):
    train_4k      seq=4096    global_batch=256   -> train_step (MARINA-P round)
    prefill_32k   seq=32768   global_batch=32    -> prefill_step (forward)
    decode_32k    seq=32768   global_batch=128   -> serve_step (1 token + cache)
    long_500k     seq=524288  global_batch=1     -> serve_step; sub-quadratic
                  natively (rwkv6, gemma3) or via the sliding-window variant
                  (window = cfg.long_context_window) for full-attention archs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.data import batch_specs as data_batch_specs
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import make_optimizer
from repro.optim.schedules import cosine_warmup
from repro.train import TrainerConfig, init_state, make_downlink, make_train_step
from . import sharding as sh
from .mesh import n_workers as mesh_n_workers

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}


def long_ctx_window(cfg: ModelConfig) -> Optional[int]:
    """Sliding-window override for 500k decode (DESIGN.md §4)."""
    if not cfg.subquadratic:
        return cfg.long_context_window  # dense/moe/vlm/audio: swa variant
    if cfg.family == "hybrid":
        return cfg.long_context_window  # zamba2: window its shared attn slots
    return None  # rwkv6 / gemma3: native


def _bf16_params_shape(cfg: ModelConfig):
    shape = jax.eval_shape(lambda k: lm.lm_init(cfg, k), jax.random.PRNGKey(0))
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16), shape)


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


@dataclasses.dataclass
class Built:
    fn: Any
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    meta: dict


def build(arch_id: str, shape_id: str, mesh: Mesh, *, downlink_spec: str = "marina:perm",
          remat: bool = True, attn_chunk: int = 512, serve_layout: str = "serve",
          remat_policy=None, train_act_model_sharded: bool = False) -> Built:
    cfg = configs.get(arch_id)
    info = SHAPES[shape_id]
    W = mesh_n_workers(mesh)

    if info["kind"] == "train":
        assert info["global_batch"] % W == 0
        bpw = info["global_batch"] // W
        act = None
        if train_act_model_sharded and bpw % mesh.shape["model"] == 0:
            act = P("model", None, None)  # within-worker batch over model axis
        tcfg = TrainerConfig(
            n_workers=W, remat=remat, attn_chunk=attn_chunk, weight_dtype=jnp.bfloat16,
            remat_policy=remat_policy, act_spec=act,
        )
        downlink = make_downlink(downlink_spec, W)
        optimizer = make_optimizer("adamw")
        lr_fn = cosine_warmup(3e-4, 200, 20000)
        step_fn = make_train_step(cfg, tcfg, downlink, optimizer, lr_fn)
        state_shape = jax.eval_shape(
            lambda k: init_state(cfg, tcfg, downlink, optimizer, k), jax.random.PRNGKey(0)
        )
        batch_shape = data_batch_specs(cfg, W, bpw, info["seq"])
        key_shape = jax.eval_shape(lambda: jax.random.PRNGKey(0))

        # build state specs by routing on the top-level key
        server_specs = sh.param_specs(state_shape["server"], mesh, "server")
        opt_specs = sh.param_specs(state_shape["opt"], mesh, "server")
        state_specs = {
            "server": server_specs,
            "opt": opt_specs,
            "step": P(),
            "bits_per_worker": P(),
        }
        if "workers" in state_shape:
            state_specs["workers"] = sh.param_specs(state_shape["workers"], mesh, "worker")
        batch_sp = sh.train_batch_spec(batch_shape, mesh)
        args = (state_shape, batch_shape, key_shape)
        in_sh = (_ns(mesh, state_specs), _ns(mesh, batch_sp), NamedSharding(mesh, P()))
        return Built(step_fn, args, in_sh, dict(cfg=cfg, W=W, bpw=bpw, **info))

    if info["kind"] == "prefill":
        B, S = info["global_batch"], info["seq"]
        params_shape = _bf16_params_shape(cfg)
        dp = sh.dp_axes_of(mesh)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        # anchor batch-parallel activations (requires use_mesh at lower time)
        act = P(dp[0] if len(dp) == 1 else dp, None, None) if B % dp_size == 0 else None

        def prefill_step(params, batch):
            return lm.forward(cfg, params, batch, chunk=attn_chunk, remat=remat,
                              act_spec=act)

        if cfg.num_codebooks:
            batch_shape = {"tokens": jax.ShapeDtypeStruct((B, cfg.num_codebooks, S), jnp.int32)}
        elif cfg.num_patches:
            batch_shape = {
                "tokens": jax.ShapeDtypeStruct((B, S - cfg.num_patches), jnp.int32),
                "patches": jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), jnp.bfloat16),
            }
        else:
            batch_shape = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        p_specs = sh.param_specs(params_shape, mesh, serve_layout)
        b_specs = sh.serve_batch_spec(batch_shape, mesh, B)
        args = (params_shape, batch_shape)
        in_sh = (_ns(mesh, p_specs), _ns(mesh, b_specs))
        return Built(prefill_step, args, in_sh, dict(cfg=cfg, **info))

    # ---- decode ---------------------------------------------------------------
    B, S = info["global_batch"], info["seq"]
    window = long_ctx_window(cfg) if shape_id == "long_500k" else None
    params_shape = _bf16_params_shape(cfg)
    caches_shape = jax.eval_shape(
        lambda: lm.cache_init(cfg, B, S, window_override=window)
    )

    def serve_step(params, caches, token, pos):
        return lm.decode_step(cfg, params, caches, token, pos, window_override=window)

    if cfg.num_codebooks:
        token_shape = jax.ShapeDtypeStruct((B, cfg.num_codebooks, 1), jnp.int32)
    else:
        token_shape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    p_specs = sh.param_specs(params_shape, mesh, serve_layout)
    c_specs = sh.cache_specs(caches_shape, mesh, B)
    t_specs = sh.serve_batch_spec(token_shape, mesh, B)
    args = (params_shape, caches_shape, token_shape, pos_shape)
    in_sh = (_ns(mesh, p_specs), _ns(mesh, c_specs), _ns(mesh, t_specs), NamedSharding(mesh, P()))
    return Built(serve_step, args, in_sh, dict(cfg=cfg, window=window, **info))
