"""Roofline terms from compiled dry-run artifacts (DESIGN.md §6).

Hardware constants (TPU v5e target):
    peak bf16 compute   197e12 FLOP/s per chip
    HBM bandwidth       819e9  B/s  per chip
    ICI link bandwidth  50e9   B/s  per link per chip

Terms per (arch × shape × mesh):
    compute    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory     = HLO_bytes   / (chips * HBM_BW)
    collective = coll_bytes  / (chips * ICI_BW)

``collective_bytes`` parses the optimized HLO text and sums operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops (cost_analysis does not report them).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# e.g.  %all-reduce.5 = f32[1024,512]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)[\.\s(]"
)
# tuple-shaped collectives:  = (f32[8,4]{..}, f32[16]{..}) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(((?:[a-z0-9]+\[[0-9,]*\][^,()]*,?\s*)+)\)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)[\.\s(]"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum of result-shape bytes per collective kind (proxy for bytes moved)."""
    out = {k: 0.0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            count[kind] += 1
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            for dm in _SHAPE_RE.finditer(shapes):
                out[kind] += _shape_bytes(*dm.groups())
            count[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count  # type: ignore[assignment]
    return out


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float) -> dict:
    """Terms in seconds from PER-DEVICE totals (the compiled module is the
    per-device SPMD program; global = per-device totals balanced across chips,
    so per-device/peak IS the global step-time bound per term)."""
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = coll_bytes_per_device / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    return terms


def model_flops(cfg, kind: str, global_batch: int, seq: int) -> float:
    """Analytic MODEL_FLOPS: 6*N*D for training, 2*N*D for inference
    (N = active params, D = tokens processed this step)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = global_batch * seq
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = global_batch * seq
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * global_batch
