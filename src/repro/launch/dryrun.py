import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes and extract roofline inputs (memory_analysis, cost_analysis,
collective bytes from optimized HLO).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out runs/dryrun]

The XLA_FLAGS line above MUST stay the first statement — jax locks the host
device count on first init (see the module-level comment in DESIGN.md §5).
"""

import argparse
import json
import time
import traceback

import jax

from repro import configs, obs
from repro.launch import hlo_cost, roofline
from repro.launch.mesh import make_hierarchical_mesh, make_production_mesh
from repro.launch.specs import SHAPES, build

log = obs.get_logger("dryrun")


def _mem_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out and ma is not None:
        out["repr"] = str(ma)
    return out


def run_one(arch: str, shape: str, *, multi_pod: bool = False, downlink: str = "marina:perm",
            verbose: bool = True, save_hlo: str | None = None,
            serve_layout: str = "serve", remat_policy=None,
            train_act_model_sharded: bool = False,
            hierarchical_workers: int = 0) -> dict:
    if hierarchical_workers:
        mesh = make_hierarchical_mesh(hierarchical_workers)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    built = build(arch, shape, mesh, downlink_spec=downlink, serve_layout=serve_layout,
                  remat_policy=remat_policy,
                  train_act_model_sharded=train_act_model_sharded)
    jitted = jax.jit(built.fn, in_shardings=built.in_shardings)
    with jax.sharding.use_abstract_mesh(mesh.abstract_mesh):
        lowered = jitted.lower(*built.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # trip-count-aware per-device totals (cost_analysis counts while bodies
    # once and misses collectives — see launch/hlo_cost.py)
    totals = hlo_cost.analyze(hlo)
    mem = _mem_analysis_dict(compiled)
    flops_dev = totals["flops"]
    bytes_dev = totals["bytes"]
    coll_dev = totals["coll_total"]
    cfg = built.meta["cfg"]
    mf = roofline.model_flops(cfg, built.meta["kind"], built.meta["global_batch"], built.meta["seq"])
    terms = roofline.roofline_terms(flops_dev, bytes_dev, coll_dev)
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": (f"wk{hierarchical_workers}x{16//hierarchical_workers}x16" if hierarchical_workers
                 else f"{'2x16x16' if multi_pod else '16x16'}"),
        "chips": chips,
        "kind": built.meta["kind"],
        "downlink": downlink if built.meta["kind"] == "train" else None,
        "serve_layout": serve_layout if built.meta["kind"] != "train" else None,
        "remat_policy": remat_policy,
        "window_override": built.meta.get("window"),
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": totals["coll"],
        "collective_total_per_device": coll_dev,
        "xla_cost_analysis": {"flops": float(cost.get("flops", 0.0)),
                              "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "memory_analysis": mem,
        "model_flops": mf,
        "useful_flops_ratio": (mf / (flops_dev * chips)) if flops_dev else None,
        "roofline": terms,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    if verbose:
        dom = terms["dominant"].replace("_s", "")
        log.info(
            f"{arch:26s} {shape:12s} mesh={rec['mesh']:8s} "
            f"compile={t_compile:6.1f}s flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e} "
            f"coll/dev={coll_dev:.3e} dominant={dom}"
        )
    # structured twin of the log line: compile timings land in the same
    # JSONL stream as benchmark events (REPRO_OBS_JSONL)
    obs.default_tracker().log(
        {
            "dryrun": {
                "arch": arch, "shape": shape, "mesh": rec["mesh"],
                "t_lower_s": t_lower, "t_compile_s": t_compile,
                "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
                "collective_total_per_device": coll_dev,
                "dominant": terms["dominant"],
            }
        }
    )
    if save_hlo:
        import gzip

        with gzip.open(save_hlo, "wt") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--downlink", default="marina:perm")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--serve-layout", default="serve", choices=["serve", "tp", "tp_attn_rep"])
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--train-act-model-sharded", action="store_true")
    ap.add_argument("--hierarchical-workers", type=int, default=0)
    args = ap.parse_args()

    archs = list(configs.ALIASES) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    log.info(f"skip (cached) {tag}")
                    continue
                try:
                    hlo_path = os.path.join(args.out, tag + ".hlo.gz") if args.save_hlo else None
                    rec = run_one(arch, shape, multi_pod=mp, downlink=args.downlink,
                                  save_hlo=hlo_path, serve_layout=args.serve_layout,
                                  remat_policy=args.remat_policy,
                                  train_act_model_sharded=args.train_act_model_sharded,
                                  hierarchical_workers=args.hierarchical_workers)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((tag, repr(e)))
    if failures:
        log.error(f"FAILURES ({len(failures)}):")
        for tag, err in failures:
            log.error(f"  {tag} {err[:200]}")
        raise SystemExit(1)
    log.info("all requested combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
