"""Trip-count-aware cost analysis over optimized (per-device) HLO text.

``compiled.cost_analysis()`` counts every while body ONCE and reports
per-partition numbers, which silently undercounts scanned layer stacks
(verified experimentally — see EXPERIMENTS.md §Dry-run). This module
re-derives per-device totals from ``compiled.as_text()``:

* flops       — 2 * |result| * |contracted dims| for every ``dot``;
                fusions/calls recursed, while bodies scaled by
                ``backend_config known_trip_count``.
* bytes       — HBM-traffic proxy: sum of (operands + result) sizes of every
                materializing op at computation top level (fusion internals
                excluded — they live in registers/VMEM), again trip-scaled.
* collectives — result bytes per collective kind, trip-scaled (a collective
                inside a scanned layer runs every iteration).

All numbers are per device (the compiled module is the SPMD per-device
program); multiply by ``mesh.size`` for global totals.
"""
from __future__ import annotations

import json
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

# ops that do not read/write HBM on their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
# control-flow ops whose bytes are accounted inside their computations
_CONTROL_OPS = {"while", "conditional", "call"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class HloCost:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[str]] = {}
        self._parse(hlo_text)
        self.entry = self._entry_name
        self._cache: Dict[str, dict] = {}

    # -- parsing ---------------------------------------------------------------
    def _parse(self, text: str):
        cur = None
        self._entry_name = None
        for line in text.splitlines():
            stripped = line.strip()
            m = re.match(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{", stripped)
            if m and not stripped.startswith("%param"):
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self._entry_name = cur
                continue
            if stripped == "}":
                cur = None
                continue
            if cur is not None and stripped:
                self.computations[cur].append(stripped)

    # -- per-computation analysis -------------------------------------------------
    def _analyze(self, comp: str) -> dict:
        if comp in self._cache:
            return self._cache[comp]
        # placeholder to break recursion on malformed input
        self._cache[comp] = {"flops": 0.0, "bytes": 0.0,
                             "coll": {k: 0.0 for k in COLLECTIVE_KINDS}}
        lines = self.computations.get(comp, [])
        symtab: Dict[str, str] = {}
        flops = 0.0
        bytes_ = 0.0
        coll = {k: 0.0 for k in COLLECTIVE_KINDS}

        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, type_str, op = dm.groups()
            symtab[name] = type_str

        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, type_str, op = dm.groups()
            result_bytes = _type_bytes(type_str)
            # operand names: everything after the op's open paren
            paren = line.find(op + "(")
            operand_str = line[paren : line.find(")", paren) + 1] if paren >= 0 else ""
            operands = _OPERAND_RE.findall(operand_str)
            operand_bytes = sum(_type_bytes(symtab.get(o, "")) for o in operands)

            if op == "dot":
                dims = _shape_dims(type_str)
                out_elems = math.prod(dims) if dims else 1
                lhs = operands[0] if operands else None
                lhs_dims = _shape_dims(symtab.get(lhs, "")) if lhs else []
                cm = _CONTRACT_RE.search(line)
                contract = 1
                if cm and lhs_dims:
                    for i in [int(x) for x in cm.group(1).split(",") if x]:
                        if i < len(lhs_dims):
                            contract *= lhs_dims[i]
                flops += 2.0 * out_elems * contract
                bytes_ += result_bytes + operand_bytes
            elif op == "fusion":
                cm = _CALLS_RE.search(line)
                if cm:
                    sub = self._analyze(cm.group(1))
                    flops += sub["flops"]  # dots inside fusions still run on MXU
                    for k in COLLECTIVE_KINDS:
                        coll[k] += sub["coll"][k]
                bytes_ += result_bytes + operand_bytes
            elif op == "while":
                bm, cm = _BODY_RE.search(line), _COND_RE.search(line)
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                body = self._analyze(bm.group(1)) if bm else None
                cond = self._analyze(cm.group(1)) if cm else None
                for sub in (body, cond):
                    if sub is None:
                        continue
                    flops += trip * sub["flops"]
                    bytes_ += trip * sub["bytes"]
                    for k in COLLECTIVE_KINDS:
                        coll[k] += trip * sub["coll"][k]
            elif op == "conditional":
                bm = _BRANCHES_RE.search(line)
                if bm:
                    subs = [self._analyze(b.strip().lstrip("%")) for b in bm.group(1).split(",")]
                    if subs:
                        # worst case branch
                        flops += max(s["flops"] for s in subs)
                        bytes_ += max(s["bytes"] for s in subs)
                        for k in COLLECTIVE_KINDS:
                            coll[k] += max(s["coll"][k] for s in subs)
            elif op == "call" or op == "async-start":
                cm = _CALLS_RE.search(line) or re.search(r"to_apply=%([\w.\-]+)", line)
                if cm:
                    sub = self._analyze(cm.group(1))
                    flops += sub["flops"]
                    bytes_ += sub["bytes"]
                    for k in COLLECTIVE_KINDS:
                        coll[k] += sub["coll"][k]
            elif any(op.startswith(k) for k in COLLECTIVE_KINDS):
                kind = next(k for k in COLLECTIVE_KINDS if op.startswith(k))
                if not op.endswith("-done"):  # avoid double count of async pairs
                    coll[kind] += result_bytes
                    bytes_ += result_bytes + operand_bytes
            elif op in ("dynamic-slice", "gather"):
                # reads only the sliced/gathered region, not the whole operand
                bytes_ += 2 * result_bytes
            elif op in ("dynamic-update-slice", "scatter"):
                # read-modify-write of the update region only
                upd = _type_bytes(symtab.get(operands[1], "")) if len(operands) > 1 else result_bytes
                bytes_ += 2 * upd
            elif op in _FREE_OPS or op in _CONTROL_OPS:
                pass
            else:
                # generic materializing op (copy, reduce, sort, ...)
                bytes_ += result_bytes + operand_bytes

        out = {"flops": flops, "bytes": bytes_, "coll": coll}
        self._cache[comp] = out
        return out

    def totals(self) -> dict:
        if self.entry is None:
            return {"flops": 0.0, "bytes": 0.0, "coll": {k: 0.0 for k in COLLECTIVE_KINDS}}
        t = self._analyze(self.entry)
        t = dict(t)
        t["coll"] = dict(t["coll"])
        t["coll_total"] = sum(t["coll"].values())
        return t


def analyze(hlo_text: str) -> dict:
    return HloCost(hlo_text).totals()
