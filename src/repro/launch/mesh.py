"""Production meshes (DESIGN.md §5).

Single pod:  (16, 16)      axes ("data", "model")        = 256 chips
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

Functions only — importing this module never touches jax device state.
The federated ``workers`` of the paper map to the ("pod","data") axes:
16 workers single-pod, 32 multi-pod (one model replica per data group).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_hierarchical_mesh(workers: int = 4):
    """§Perf C4 variant: 256 chips as (wk, data, model) = (workers, 16//?, 16).

    MARINA-P workers live on the small ``wk`` axis; each worker's replica is
    additionally FSDP-sharded over ``data`` — replica residency /= data size,
    and Theorem 2's omega drops from 15 to workers-1.
    """
    assert 16 % workers == 0
    return jax.make_mesh((workers, 16 // workers, 16), ("wk", "data", "model"))


def worker_axes(mesh) -> tuple:
    """Mesh axes that enumerate federated workers."""
    if "wk" in mesh.axis_names:
        return ("wk",)
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_workers(mesh) -> int:
    n = 1
    for a in worker_axes(mesh):
        n *= mesh.shape[a]
    return n


def make_worker_mesh(n: int = 0):
    """1-D workers mesh for the core-algorithm SPMD runtime (core/distributed)."""
    import numpy as np

    devs = np.array(jax.devices())
    if n:
        devs = devs[:n]
    return jax.sharding.Mesh(devs, ("workers",))
