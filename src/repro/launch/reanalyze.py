"""Re-derive roofline terms from saved .hlo.gz artifacts (no recompilation).

Usage: PYTHONPATH=src python -m repro.launch.reanalyze runs/dryrun_v2 [out_dir]
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from repro import obs
from repro.launch import hlo_cost, roofline

log = obs.get_logger("reanalyze")


def reanalyze(dirpath: str, out_dir: str | None = None):
    out_dir = out_dir or dirpath
    os.makedirs(out_dir, exist_ok=True)
    for jf in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        hf = jf.replace(".json", ".hlo.gz")
        if not os.path.exists(hf):
            continue
        rec = json.load(open(jf))
        totals = hlo_cost.analyze(gzip.open(hf, "rt").read())
        rec["flops_per_device"] = totals["flops"]
        rec["bytes_per_device"] = totals["bytes"]
        rec["collective_bytes_per_device"] = totals["coll"]
        rec["collective_total_per_device"] = totals["coll_total"]
        rec["roofline"] = roofline.roofline_terms(
            totals["flops"], totals["bytes"], totals["coll_total"]
        )
        if rec.get("model_flops") and totals["flops"]:
            rec["useful_flops_ratio"] = rec["model_flops"] / (totals["flops"] * rec["chips"])
        out = os.path.join(out_dir, os.path.basename(jf))
        json.dump(rec, open(out, "w"), indent=1)
        t = rec["roofline"]
        log.info(f"{rec['arch']:26s} {rec['shape']:12s} {rec['mesh']:8s} "
                 f"dom={t['dominant'][:4]} bound={t['bound_s']:.3e}")
        obs.default_tracker().log(
            {
                "reanalyze": {
                    "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
                    "flops_per_device": rec["flops_per_device"],
                    "bytes_per_device": rec["bytes_per_device"],
                    "bound_s": t["bound_s"], "dominant": t["dominant"],
                }
            }
        )


if __name__ == "__main__":
    reanalyze(sys.argv[1], sys.argv[2] if len(sys.argv) > 2 else None)
