"""NATURAL codec: sign + fp32 exponent, 9 bits per value (DESIGN.md §3.3).

Natural compression (Horvath et al. 2022) rounds every value to a signed
power of two, so the fp32 mantissa of its output is always zero: the wire
only needs [sign:1][biased exponent:8] per coordinate — exactly the
9 bits/value of ``CommModel.natural_bits``. Zero is exponent field 0
(fp32 zero/subnormal band; natural compression never emits subnormals).

Payload after the common header: one 9-bit token stream, word-aligned.
Encoding a value with a non-zero mantissa silently drops the mantissa —
the codec is only exact on natural-compression outputs (tested).
"""
from __future__ import annotations

import numpy as np

from . import bitstream as bs
from .spec import CodecID, TruncatedFrame, pack_header

TOKEN_BITS = 9


def encode_natural(x) -> bytes:
    v = np.ascontiguousarray(np.asarray(x), dtype=np.float32).reshape(-1)
    bits = v.view("<u4")
    sign = bits >> np.uint32(31)
    exp = (bits >> np.uint32(23)) & np.uint32(0xFF)
    token = (sign << np.uint32(8)) | exp
    return pack_header(CodecID.NATURAL, v.size) + bs.to_bytes(
        bs.pack_u32(token, TOKEN_BITS)
    )


def decode_natural(buf: bytes, offset: int, d: int) -> np.ndarray:
    if len(buf) < offset + 4 * bs.n_words(d, TOKEN_BITS):
        raise TruncatedFrame("truncated natural wire message")
    words = bs.from_bytes(buf[offset : offset + 4 * bs.n_words(d, TOKEN_BITS)])
    token = bs.unpack_u32(words, TOKEN_BITS, d)
    sign = token >> np.uint32(8)
    exp = token & np.uint32(0xFF)
    bits = (sign << np.uint32(31)) | (exp << np.uint32(23))
    return bits.astype("<u4").view(np.float32).copy()
