"""Wire format constants, header layout and message specs (DESIGN.md §3).

Every message starts with an 8-byte common header:

    [u16 magic = 0x5749 ("WI")] [u8 version] [u8 codec_id] [u32 d]

followed by a codec-specific payload. All integers are little-endian;
all bit streams follow bitstream.py's LSB-first uint32-word convention.
"""
from __future__ import annotations

import dataclasses
import enum
import math
import struct

MAGIC = 0x5749  # "WI"
VERSION = 1

_HEADER = struct.Struct("<HBBI")
HEADER_BYTES = _HEADER.size  # 8


class WireError(ValueError):
    """Base class for wire-level decode failures.

    Subclasses ``ValueError`` so pre-hierarchy callers keep working; the
    transport layer (repro.transport) catches the subclasses to tell a
    retransmit-recoverable failure from a poisoned message.
    """


class TruncatedFrame(WireError):
    """Buffer ended before the declared payload — recoverable: the rest of
    the message may still arrive (or a retransmit will carry it whole)."""


class CorruptFrame(WireError):
    """Contents fail validation (magic / version / CRC / field range) —
    the message itself is damaged and must be retransmitted or resynced."""


class CodecID(enum.IntEnum):
    SPARSE = 1   # (index, sign, magnitude) streams
    SEED = 2     # shared-randomness coordinates, O(1) bytes
    NATURAL = 3  # sign + fp32 exponent, 9 bits/value, dense
    DENSE = 4    # raw values, dense


class MagDType(enum.IntEnum):
    """Magnitude dtype selector for SPARSE/DENSE payloads."""

    FP32 = 0
    FP16 = 1
    BF16 = 2


#: wire bits per magnitude for each dtype selector
MAG_BITS = {MagDType.FP32: 32, MagDType.FP16: 16, MagDType.BF16: 16}

_MAG_NAMES = {"fp32": MagDType.FP32, "fp16": MagDType.FP16, "bf16": MagDType.BF16}


def mag_dtype(name_or_enum) -> MagDType:
    if isinstance(name_or_enum, MagDType):
        return name_or_enum
    return _MAG_NAMES[str(name_or_enum)]


class SeedFamily(enum.IntEnum):
    """Shared-randomness compressor families the SEED codec can carry."""

    BERN = 0   # counter-hash Bernoulli mask (kernels/randk.py)
    ROTK = 1   # cyclic partition with shared rotation
    PERM = 2   # Definition 5 PermK via a jax.random permutation


@dataclasses.dataclass(frozen=True)
class SeedMessage:
    """O(1) downlink message for shared-randomness compressors.

    The receiver already holds the (replicated) ``delta``; these fields are
    the RNG coordinates it needs to rematerialize its mask locally
    (DESIGN.md §2). ``param`` is family-specific: keep_prob for BERN,
    rotation for ROTK, unused for PERM.
    """

    family: SeedFamily
    seed: int          # uint32 counter seed / PRNGKey seed
    round: int         # uint32 round counter (folded into the key)
    scale: float       # multiplier applied to kept coordinates
    n: int             # worker-family size
    worker: int        # receiver's worker index
    param: float = 0.0


def index_width(d: int) -> int:
    """ceil(log2 d) bits per coordinate index (min 1)."""
    return max(1, math.ceil(math.log2(max(d, 2))))


def pack_header(codec: CodecID, d: int) -> bytes:
    return _HEADER.pack(MAGIC, VERSION, int(codec), d)


def unpack_header(buf: bytes) -> tuple[CodecID, int]:
    if len(buf) < HEADER_BYTES:
        raise TruncatedFrame("truncated wire message (no header)")
    magic, version, codec, d = _HEADER.unpack_from(buf, 0)
    if magic != MAGIC:
        raise CorruptFrame(f"bad magic {magic:#x}")
    if version != VERSION:
        raise CorruptFrame(f"unsupported wire version {version}")
    try:
        codec = CodecID(codec)
    except ValueError as e:
        raise CorruptFrame(f"unknown codec id {codec}") from e
    return codec, d
