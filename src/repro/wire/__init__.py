"""repro.wire — packed bitstream codecs for compressed downlink messages.

Turns the repo's *analytic* bit accounting (core/comm_model.py) into real
packed byte buffers that can be measured, transported and decoded exactly:

* SPARSE  — (index: ceil(log2 d) bits, sign: 1 bit, magnitude:
  fp32/fp16/bf16) for RandK / TopK / BlockTopK messages;
* SEED    — O(1) bytes of RNG coordinates for shared-randomness families
  (BernK / RotK / PermK); the receiver rematerializes its mask locally;
* NATURAL — sign + exponent, 9 bits/value, for natural compression;
* DENSE   — raw values for full-sync broadcast rounds.

Layout reference: DESIGN.md §3. Device-side pack/unpack kernels:
kernels/pack.py. Measured-vs-analytic parity: benchmarks/wire_bench.py.
"""
from .bitstream import from_bytes, n_words, pack_u32, to_bytes, unpack_u32  # noqa: F401
from .natural import decode_natural, encode_natural  # noqa: F401
from .registry import codec_for, decode, encode, peek  # noqa: F401
from .seedonly import apply_seed, decode_seed, encode_seed  # noqa: F401
from .sparse import decode_dense, decode_sparse, encode_dense, encode_sparse  # noqa: F401
from .spec import (  # noqa: F401
    HEADER_BYTES,
    MAG_BITS,
    CodecID,
    CorruptFrame,
    MagDType,
    SeedFamily,
    SeedMessage,
    TruncatedFrame,
    WireError,
    index_width,
    mag_dtype,
)


def measured_bits(buf: bytes) -> int:
    """Wire size of an encoded message, in bits."""
    return 8 * len(buf)
