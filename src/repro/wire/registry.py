"""Codec registry and top-level encode/decode dispatch.

``decode(buf)`` inspects the common header and routes to the right codec.
Payload-carrying codecs (SPARSE / NATURAL / DENSE) decode to a dense fp32
vector standalone; the SEED codec needs the receiver-local ``delta``
(DESIGN.md §2) and raises without it.

``codec_for`` maps compressor families (core/compressors.py) to their
natural wire codec, so callers can serialize any compressor output without
hand-picking a format.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import compressors as C

from .natural import decode_natural, encode_natural
from .seedonly import apply_seed, decode_seed, encode_seed
from .sparse import decode_dense, decode_sparse, encode_dense, encode_sparse
from .spec import HEADER_BYTES, CodecID, SeedMessage, unpack_header


def decode(buf: bytes, *, delta=None) -> np.ndarray:
    """Decode a wire message to a dense fp32 vector [d].

    ``delta`` (receiver-local replicated vector) is required for SEED
    messages and ignored otherwise.
    """
    codec, d = unpack_header(buf)
    if codec == CodecID.SPARSE:
        return decode_sparse(buf, HEADER_BYTES, d)
    if codec == CodecID.NATURAL:
        return decode_natural(buf, HEADER_BYTES, d)
    if codec == CodecID.DENSE:
        return decode_dense(buf, HEADER_BYTES, d)
    if codec == CodecID.SEED:
        if delta is None:
            raise ValueError(
                "SEED message needs the receiver-local delta to rematerialize"
            )
        msg = decode_seed(buf, HEADER_BYTES, d)
        return apply_seed(msg, delta)
    raise ValueError(codec)  # pragma: no cover


def peek(buf: bytes) -> tuple[CodecID, int]:
    """(codec, d) of a message without decoding the payload."""
    return unpack_header(buf)


def codec_for(comp: C.Compressor) -> CodecID:
    """The natural wire codec for a compressor family."""
    if isinstance(comp, (C.BernK, C.RotK, C.PermK)):
        return CodecID.SEED
    if isinstance(comp, C.NaturalCompression):
        return CodecID.NATURAL
    if isinstance(comp, C.Identity):
        return CodecID.DENSE
    if isinstance(comp, (C.TopK, C.BlockTopK, C.RandK, C.ScaledUnbiased)):
        return CodecID.SPARSE
    return CodecID.SPARSE


def _device_encodable(x) -> bool:
    """True when ``x`` is a jax array the fused device encoder can take
    without a host round-trip first."""
    try:
        import jax
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        return False
    return isinstance(x, jax.Array)


def encode(x, comp: Optional[C.Compressor] = None, *, mag="fp32",
           device_encode: Optional[bool] = None) -> bytes:
    """Encode a compressor output with its family's natural payload codec.

    SEED-family compressors still encode here as SPARSE (explicit payload):
    producing a true O(1) SEED message requires the RNG coordinates, not
    just the output — use :func:`repro.wire.encode_seed` with a
    :class:`SeedMessage` for that path.

    ``device_encode`` selects the fused Pallas encode path
    (kernels/encode.py) for SPARSE/DENSE payloads when ``x`` is already a
    device array: True forces it, False forces the host numpy codec, None
    defers to ``REPRO_DEVICE_ENCODE`` / backend auto-detection. Both paths
    produce byte-identical streams (tests/test_encode_diff.py).
    """
    codec = codec_for(comp) if comp is not None else CodecID.SPARSE
    if codec == CodecID.NATURAL:
        return encode_natural(x)
    if codec in (CodecID.DENSE, CodecID.SPARSE) and _device_encodable(x):
        from repro.kernels import encode as kenc

        if kenc.device_encode_enabled(device_encode):
            if codec == CodecID.DENSE:
                return kenc.dense_encode(x, mag=mag)
            return kenc.sparse_encode(x, mag=mag)
    if codec == CodecID.DENSE:
        return encode_dense(x, mag=mag)
    return encode_sparse(x, mag=mag)
