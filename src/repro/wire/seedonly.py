"""SEED codec: O(1)-byte messages for shared-randomness compressors
(DESIGN.md §2 / §3.2).

BernK / RotK / PermK masks are pure functions of (seed, round, worker), so
the downlink message need not carry indices or values at all when the
receiver already holds the replicated ``delta`` (the SPMD realization in
core/distributed.py): it transmits the RNG coordinates and the receiver
rematerializes its slice locally. The BERN family reuses the counter hash
from kernels/randk.py bit-for-bit, so a receiver decoding on-TPU via the
Pallas bernk kernel produces the identical mask.

Payload after the common header (28 bytes, fixed):

    [u8 family][pad x3][u32 seed][u32 round][f32 scale]
    [u32 n][u32 worker][f32 param]
"""
from __future__ import annotations

import struct

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.randk import hash_uniform

from .spec import CodecID, CorruptFrame, SeedFamily, SeedMessage, TruncatedFrame, pack_header

_PAYLOAD = struct.Struct("<BxxxIIfIIf")


def encode_seed(msg: SeedMessage, d: int) -> bytes:
    return pack_header(CodecID.SEED, d) + _PAYLOAD.pack(
        int(msg.family),
        msg.seed & 0xFFFFFFFF,
        msg.round & 0xFFFFFFFF,
        msg.scale,
        msg.n,
        msg.worker,
        msg.param,
    )


def decode_seed(buf: bytes, offset: int, d: int) -> SeedMessage:
    if len(buf) < offset + _PAYLOAD.size:
        raise TruncatedFrame("truncated seed wire message")
    family, seed, rnd, scale, n, worker, param = _PAYLOAD.unpack_from(buf, offset)
    try:
        family = SeedFamily(family)
    except ValueError as e:
        raise CorruptFrame(f"corrupt seed wire message: bad family {family}") from e
    return SeedMessage(
        family=family, seed=seed, round=rnd, scale=scale,
        n=n, worker=worker, param=param,
    )


def apply_seed(msg: SeedMessage, delta) -> np.ndarray:
    """Rematerialize the mask from the RNG coordinates and apply it to the
    receiver-local ``delta``: Q_i(delta) without any index/value payload."""
    x = np.ascontiguousarray(np.asarray(delta), dtype=np.float32).reshape(-1)
    d = x.size
    if msg.family == SeedFamily.BERN:
        idx = jnp.arange(d, dtype=jnp.uint32)
        u = np.asarray(hash_uniform(idx, msg.seed + msg.round, msg.worker))
        out = np.where(u < msg.param, x / msg.param, 0.0)
    elif msg.family == SeedFamily.ROTK:
        r = int(msg.param)
        keep = (np.arange(d) % msg.n) == ((msg.worker + r) % msg.n)
        out = np.where(keep, x * msg.n, 0.0)
    elif msg.family == SeedFamily.PERM:
        from repro.core.compressors import PermK

        key = jax.random.fold_in(jax.random.PRNGKey(msg.seed), msg.round)
        out = np.asarray(PermK(n=msg.n, worker=msg.worker)(key, jnp.asarray(x)))
    else:  # pragma: no cover
        raise ValueError(msg.family)
    return (out * msg.scale).astype(np.float32)
