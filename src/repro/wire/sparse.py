"""SPARSE codec: (index, sign, magnitude) streams for explicit-support
messages — RandK / TopK / BlockTopK downlink deltas (DESIGN.md §3.1).

Payload after the common header:

    [u8 mag_dtype] [u8 pad x3] [u32 count]
    [index stream:     count * ceil(log2 d) bits, word-aligned]
    [sign stream:      count * 1 bit,             word-aligned]
    [magnitude stream: count * MAG_BITS bits,     word-aligned]

This mirrors the paper's analytic bit model (value_bits + 1 + log2 d per
non-zero): sign is carried separately from the |value| bits, exactly as
Definition 1 counts it. fp32 magnitudes round-trip bit-exactly; fp16/bf16
round the magnitude to the wire dtype (the decoder returns fp32).
"""
from __future__ import annotations

import struct

import numpy as np

from . import bitstream as bs
from .spec import (
    CodecID,
    CorruptFrame,
    MAG_BITS,
    MagDType,
    TruncatedFrame,
    index_width,
    mag_dtype,
    pack_header,
)

_PAYLOAD = struct.Struct("<BxxxI")

try:  # bf16 comes with jax (ml_dtypes is a hard dependency of jax)
    import ml_dtypes

    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    _BF16 = None


def _mag_np_dtype(m: MagDType):
    if m == MagDType.FP32:
        return np.dtype(np.float32), np.dtype("<u4")
    if m == MagDType.FP16:
        return np.dtype(np.float16), np.dtype("<u2")
    if _BF16 is None:
        raise RuntimeError("bf16 wire dtype needs ml_dtypes")
    return _BF16, np.dtype("<u2")


def encode_sparse(x, *, mag="fp32") -> bytes:
    """Encode a dense sparsified fp32 vector (zeros are elided)."""
    m = mag_dtype(mag)
    v = np.ascontiguousarray(np.asarray(x), dtype=np.float32).reshape(-1)
    d = v.size
    idx = np.nonzero(v)[0].astype(np.uint32)
    vals = v[idx]
    sign = np.signbit(vals).astype(np.uint32)
    fdt, udt = _mag_np_dtype(m)
    magbits = np.abs(vals).astype(fdt).view(udt).astype(np.uint32)
    parts = [
        pack_header(CodecID.SPARSE, d),
        _PAYLOAD.pack(int(m), idx.size),
        bs.to_bytes(bs.pack_u32(idx, index_width(d))),
        bs.to_bytes(bs.pack_u32(sign, 1)),
        bs.to_bytes(bs.pack_u32(magbits, MAG_BITS[m])),
    ]
    return b"".join(parts)


def decode_sparse(buf: bytes, offset: int, d: int) -> np.ndarray:
    """Decode the payload at ``offset`` (past the common header) -> fp32 [d]."""
    if len(buf) < offset + _PAYLOAD.size:
        raise TruncatedFrame("truncated sparse wire message")
    m, count = _PAYLOAD.unpack_from(buf, offset)
    try:
        m = MagDType(m)
    except ValueError as e:
        raise CorruptFrame(f"corrupt sparse wire message: bad mag dtype {m}") from e
    offset += _PAYLOAD.size
    if count > d:
        raise CorruptFrame(f"corrupt sparse wire message: count {count} > d={d}")
    iw = index_width(d)
    need = sum(4 * bs.n_words(count, w) for w in (iw, 1, MAG_BITS[m]))
    if len(buf) < offset + need:
        raise TruncatedFrame("truncated sparse wire message")
    streams = []
    for width, n in ((iw, count), (1, count), (MAG_BITS[m], count)):
        nbytes = 4 * bs.n_words(n, width)
        words = bs.from_bytes(buf[offset : offset + nbytes])
        streams.append(bs.unpack_u32(words, width, n))
        offset += nbytes
    idx, sign, magbits = streams
    if idx.size and int(idx.max()) >= d:
        raise CorruptFrame(f"corrupt sparse wire message: index {int(idx.max())} >= d={d}")
    fdt, udt = _mag_np_dtype(m)
    mags = magbits.astype({2: np.uint16, 4: np.uint32}[udt.itemsize]).view(fdt)
    vals = mags.astype(np.float32)
    vals = np.where(sign.astype(bool), -vals, vals)
    out = np.zeros(d, dtype=np.float32)
    out[idx] = vals
    return out


def encode_dense(x, *, mag="fp32") -> bytes:
    """DENSE codec: raw values (full-sync broadcast rounds)."""
    m = mag_dtype(mag)
    v = np.ascontiguousarray(np.asarray(x), dtype=np.float32).reshape(-1)
    fdt, udt = _mag_np_dtype(m)
    bits = v.astype(fdt).view(udt).astype(np.uint32)
    return b"".join(
        [
            pack_header(CodecID.DENSE, v.size),
            struct.pack("<Bxxx", int(m)),
            bs.to_bytes(bs.pack_u32(bits, MAG_BITS[m])),
        ]
    )


def decode_dense(buf: bytes, offset: int, d: int) -> np.ndarray:
    if len(buf) < offset + 4:
        raise TruncatedFrame("truncated dense wire message")
    (m,) = struct.unpack_from("<Bxxx", buf, offset)
    try:
        m = MagDType(m)
    except ValueError as e:
        raise CorruptFrame(f"corrupt dense wire message: bad mag dtype {m}") from e
    offset += 4
    if len(buf) < offset + 4 * bs.n_words(d, MAG_BITS[m]):
        raise TruncatedFrame("truncated dense wire message")
    words = bs.from_bytes(buf[offset : offset + 4 * bs.n_words(d, MAG_BITS[m])])
    bits = bs.unpack_u32(words, MAG_BITS[m], d)
    fdt, udt = _mag_np_dtype(m)
    vals = bits.astype({2: np.uint16, 4: np.uint32}[udt.itemsize]).view(fdt)
    return vals.astype(np.float32)
