"""Host-side bit packing: LSB-first into little-endian uint32 words.

This is the single bit-layout convention of the wire format (DESIGN.md §3):
value ``i`` of width ``w`` occupies absolute bit positions
``[i*w, (i+1)*w)``; bit ``b`` lives in word ``b // 32`` at in-word offset
``b % 32`` (LSB-first). The Pallas kernels in ``kernels/pack.py`` implement
the identical layout on-device, so host- and device-produced streams are
byte-interchangeable (asserted in tests/test_wire.py).

Every stream starts word-aligned; codecs concatenate per-field streams
(indices, signs, magnitudes) with word padding between them so each can be
packed/unpacked as one vectorized call.
"""
from __future__ import annotations

import numpy as np

_WORD = np.dtype("<u4")


def n_words(count: int, width: int) -> int:
    """Words needed for ``count`` values of ``width`` bits each."""
    return -(-count * width // 32)


def pack_u32(values: np.ndarray, width: int) -> np.ndarray:
    """Pack ``values`` (uint-like, each < 2**width) into little-endian uint32
    words, LSB-first. width in [1, 32]."""
    assert 1 <= width <= 32, width
    v = np.ascontiguousarray(values, dtype=np.uint64)
    if width < 32:
        assert v.size == 0 or int(v.max()) < (1 << width), "value overflows width"
    n = v.size
    nw = n_words(n, width)
    pos = np.arange(n, dtype=np.uint64) * np.uint64(width)
    word = (pos >> np.uint64(5)).astype(np.int64)
    off = pos & np.uint64(31)
    shifted = v << off  # fits in uint64: width + 31 <= 63
    out = np.zeros(nw + 1, dtype=np.uint64)
    np.add.at(out, word, shifted & np.uint64(0xFFFFFFFF))
    np.add.at(out, word + 1, shifted >> np.uint64(32))
    return out[:nw].astype(_WORD)


def unpack_u32(words: np.ndarray, width: int, count: int) -> np.ndarray:
    """Inverse of :func:`pack_u32`: read ``count`` values of ``width`` bits."""
    assert 1 <= width <= 32, width
    w = np.concatenate([np.ascontiguousarray(words, dtype=_WORD), np.zeros(1, _WORD)])
    w64 = w.astype(np.uint64)
    pos = np.arange(count, dtype=np.uint64) * np.uint64(width)
    word = (pos >> np.uint64(5)).astype(np.int64)
    off = pos & np.uint64(31)
    v = (w64[word] >> off) | (w64[word + 1] << (np.uint64(32) - off))
    mask = np.uint64((1 << width) - 1)
    return (v & mask).astype(np.uint32)


def to_bytes(words: np.ndarray) -> bytes:
    return np.ascontiguousarray(words, dtype=_WORD).tobytes()


def from_bytes(buf: bytes) -> np.ndarray:
    if len(buf) % 4 != 0:
        from .spec import TruncatedFrame

        raise TruncatedFrame(f"bitstream not word-aligned ({len(buf)} bytes)")
    return np.frombuffer(buf, dtype=_WORD)
