from .engine import DecodeEngine, apply_wire_delta, greedy_sample, temperature_sample  # noqa: F401
