from .engine import DecodeEngine, greedy_sample, temperature_sample  # noqa: F401
