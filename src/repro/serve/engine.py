"""Batched decode engine over ``lm.decode_step``.

Prefill scans decode_step over the prompt (cache-filling), generation scans
with sampling. Everything is jitted; the engine serves fixed-batch request
groups (continuous batching is out of scope — requests are padded to a
common prompt length).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.flatten_util
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


def apply_wire_delta(params, buf: bytes):
    """Apply a decoded repro.wire downlink message to a parameter pytree.

    The serving-side endpoint of the compressed model broadcast: a training
    server emits packed wire messages (SPARSE / NATURAL / DENSE over the
    raveled tree); each replica decodes and adds the delta to its params.
    SEED messages are rejected — they presume the receiver already holds
    the replicated delta (a training worker, not a serving replica); see
    DESIGN.md §3.2.
    """
    import numpy as np

    from repro import wire

    codec, d = wire.peek(buf)
    if codec == wire.CodecID.SEED:
        raise ValueError(
            "SEED wire messages carry no payload; serving replicas need a "
            "payload codec (SPARSE/NATURAL/DENSE)"
        )
    flat, unravel = jax.flatten_util.ravel_pytree(params)
    if d != flat.shape[-1]:
        raise ValueError(f"wire message dimension {d} != param count {flat.shape[-1]}")
    # Validate fully before mutating: decode to scratch, check it, then swap.
    # A truncated/corrupt buffer raises inside decode; a syntactically valid
    # buffer carrying non-finite magnitudes is rejected here so the served
    # params are never poisoned by a half-applied update.
    delta = wire.decode(buf)
    if not np.all(np.isfinite(delta)):
        raise wire.CorruptFrame("wire delta carries non-finite values")
    return unravel(flat + jnp.asarray(delta, flat.dtype))


def apply_wire_sync(params, buf: bytes):
    """Replace a parameter pytree with a full-model wire message.

    The payload of a transport SYNC frame is self-contained — the complete
    raveled model, not a difference — so it overwrites rather than adds
    (that is what makes it repair a replica that missed deltas). Same
    validate-before-mutate discipline as :func:`apply_wire_delta`.
    """
    import numpy as np

    from repro import wire

    codec, d = wire.peek(buf)
    if codec == wire.CodecID.SEED:
        raise ValueError("SEED wire messages cannot carry a full model")
    flat, unravel = jax.flatten_util.ravel_pytree(params)
    if d != flat.shape[-1]:
        raise ValueError(f"wire message dimension {d} != param count {flat.shape[-1]}")
    full = wire.decode(buf)
    if not np.all(np.isfinite(full)):
        raise wire.CorruptFrame("wire sync carries non-finite values")
    return unravel(jnp.asarray(full, flat.dtype))


def greedy_sample(key, logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(temp: float = 1.0):
    def fn(key, logits):
        return jax.random.categorical(key, logits.astype(jnp.float32) / temp, axis=-1).astype(jnp.int32)

    return fn


@dataclasses.dataclass
class DecodeEngine:
    cfg: ModelConfig
    params: dict
    cache_len: int
    batch_size: int
    window_override: Optional[int] = None
    sample_fn: Callable = greedy_sample
    tracker: Optional[object] = None  # repro.obs.Tracker: request latency telemetry

    def __post_init__(self):
        cfg = self.cfg

        def prefill(params, caches, tokens):
            # tokens: [B, S] (or [B, K, S]); scan one position at a time
            S = tokens.shape[-1]

            def body(carry, i):
                caches = carry
                tok = jax.lax.dynamic_index_in_dim(tokens, i, axis=-1, keepdims=True)
                logits, caches = lm.decode_step(
                    cfg, params, caches, tok, i, window_override=self.window_override
                )
                return caches, logits

            caches, logits = jax.lax.scan(body, caches, jnp.arange(S))
            return caches, logits[-1]

        def generate(params, caches, last_logits, start_pos, key, n_steps):
            def body(carry, i):
                caches, logits, key = carry
                key, sub = jax.random.split(key)
                tok = self.sample_fn(sub, logits)
                tok = tok[..., None] if tok.ndim < 2 or cfg.num_codebooks else tok
                if cfg.num_codebooks:
                    tok = tok.reshape(tok.shape[0], cfg.num_codebooks, 1)
                else:
                    tok = tok.reshape(tok.shape[0], 1)
                logits, caches = lm.decode_step(
                    cfg, params, caches, tok, start_pos + i, window_override=self.window_override
                )
                return (caches, logits, key), tok[..., 0]

            (caches, logits, _), toks = jax.lax.scan(
                body, (caches, last_logits, key), jnp.arange(n_steps)
            )
            return caches, logits, jnp.moveaxis(toks, 0, -1)  # [B, ..., n_steps]

        self._prefill = jax.jit(prefill)
        self._generate = jax.jit(generate, static_argnums=(5,))

    def fresh_caches(self):
        return lm.cache_init(
            self.cfg, self.batch_size, self.cache_len, window_override=self.window_override
        )

    _delta_seq: Optional[int] = dataclasses.field(default=None, init=False)

    def delta_sync(self, buf: bytes) -> None:
        """Apply a wire delta message to the served params in place
        (compressed model-update downlink from a training server).

        ``buf`` may be a bare wire message or a transport frame
        (DESIGN.md §8). Framed deltas are sequence-gated: a DATA frame at
        or below the last applied sequence raises
        :class:`~repro.transport.StaleDelta` (duplicate / out-of-order
        delivery must not be re-applied — deltas are not idempotent), and
        a DATA frame that skips ahead raises
        :class:`~repro.transport.SequenceGap` (a missed delta means the
        replica needs a resync, not a silent apply). SYNC frames carry
        the full model (self-contained — :func:`apply_wire_sync`
        replaces rather than adds), are accepted at any forward
        sequence, and reset the gate. The params are only mutated after
        the payload fully validates (decode-to-scratch first)."""
        from repro import transport
        from repro.obs.trace import maybe_attr, maybe_span

        with maybe_span(self.tracker, "serve/delta_sync",
                        bytes=len(buf)) as sp:
            self._delta_sync(bytes(buf), transport, sp, maybe_attr)

    def _delta_sync(self, buf: bytes, transport, sp, maybe_attr) -> None:
        if transport.is_frame(bytes(buf)):
            frame, _ = transport.decode_frame(bytes(buf))
            if frame.ftype == transport.FrameType.SYNC:
                if self._delta_seq is not None and frame.seq <= self._delta_seq:
                    raise transport.StaleDelta(
                        f"sync seq {frame.seq} <= last applied {self._delta_seq}"
                    )
                self.params = apply_wire_sync(self.params, frame.payload)
                self._delta_seq = frame.seq
                maybe_attr(sp, ftype="SYNC", seq=frame.seq)
                return
            if frame.ftype == transport.FrameType.DATA:
                if self._delta_seq is not None:
                    if frame.seq <= self._delta_seq:
                        raise transport.StaleDelta(
                            f"delta seq {frame.seq} <= last applied {self._delta_seq}"
                        )
                    if frame.seq != self._delta_seq + 1:
                        raise transport.SequenceGap(
                            f"delta seq {frame.seq} skips past "
                            f"{self._delta_seq + 1}; resync required"
                        )
            else:
                raise ValueError(f"frame type {frame.ftype!r} carries no delta")
            self.params = apply_wire_delta(self.params, frame.payload)
            self._delta_seq = frame.seq
            maybe_attr(sp, ftype="DATA", seq=frame.seq)
        else:
            self.params = apply_wire_delta(self.params, buf)
            maybe_attr(sp, ftype="bare")

    def run(self, prompts: jax.Array, n_new_tokens: int, seed: int = 0):
        """prompts: [B, S] (or [B, K, S]). Returns generated tokens [B, n].

        With a ``tracker`` attached, each request logs prefill/decode
        latency ("serve/prefill", "serve/decode" timer events — BENCH
        aggregation turns repeats into p50/p99) plus a tokens/s metric,
        and emits a "serve/request" span with "prefill"/"decode" children
        (DESIGN.md §10 — the span names are distinct from the timer names
        so the two event streams cannot collide in aggregation).
        """
        from repro import obs
        from repro.obs.trace import maybe_attr, span

        tracker = self.tracker or obs.NullTracker()
        caches = self.fresh_caches()
        with span(tracker, "serve/request", batch=prompts.shape[0],
                  prompt_len=prompts.shape[-1],
                  new_tokens=n_new_tokens) as rsp:
            with span(tracker, "prefill"):
                with tracker.time_block("serve/prefill") as tb:
                    caches, last_logits = self._prefill(
                        self.params, caches, prompts)
                    tb.block(last_logits)
                prefill_s = tb.seconds
            start = prompts.shape[-1]
            with span(tracker, "decode"):
                with tracker.time_block("serve/decode") as tb:
                    _, _, toks = self._generate(
                        self.params, caches, last_logits, start,
                        jax.random.PRNGKey(seed), n_new_tokens
                    )
                    tb.block(toks)
                decode_s = tb.seconds
            total = prefill_s + decode_s
            tokens_per_s = (
                prompts.shape[0] * n_new_tokens / decode_s if decode_s > 0 else 0.0
            )
            maybe_attr(rsp, tokens_per_s=tokens_per_s)
        tracker.log(
            {
                "serve/request_s": total,
                "serve/tokens_per_s": tokens_per_s,
                "serve/batch": prompts.shape[0],
                "serve/prompt_len": prompts.shape[-1],
                "serve/new_tokens": n_new_tokens,
            }
        )
        return toks
