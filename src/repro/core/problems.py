"""The paper's experimental workload (Section 5 / Appendix A).

Non-smooth convex finite-sum:  f(x) = (1/n) sum_i f_i(x),
f_i(x) = ||A_i x||_1  with symmetric A_i in R^{dxd}.

Known facts used by the paper (and our tests):
* x* = 0, f(x*) = 0.
* subgradient:  df_i(x) = A_i^T sign(A_i x)  (Beck 2017, Ex. 3.44), with the
  paper's sign convention sign(0) = +1 (eq. 32).
* Lipschitz estimates: L_{0,i} ~ ||A_i||_2 (spectral norm), L0 = mean_i L_{0,i},
  Ltil0 = sqrt(mean_i L_{0,i}^2).

Data generation follows Algorithm 3 exactly: per-worker scaled tridiagonal
matrices with Gaussian noise ``nu_i = 1 + s xi_i``, shifted so the mean matrix
has minimum eigenvalue mu = 1e-6, plus the dissimilarity measure sigma_A (31).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def paper_sign(x):
    """Componentwise sign with sign(0) = +1 (paper eq. 32)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class L1Problem:
    """Bundle of worker matrices A: [n, d, d] plus Lipschitz metadata."""

    A: jax.Array  # [n, d, d]
    x0: jax.Array  # [d]
    L0i: jax.Array  # [n] spectral norms
    sigma_A: float

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def d(self) -> int:
        return self.A.shape[1]

    @property
    def L0(self) -> float:
        return float(jnp.mean(self.L0i))

    @property
    def L0_tilde(self) -> float:
        return float(jnp.sqrt(jnp.mean(self.L0i**2)))

    # -- oracles --------------------------------------------------------------

    def f_i(self, i, x):
        return jnp.sum(jnp.abs(self.A[i] @ x))

    def f_all(self, xs):
        """f_i(x_i) for per-worker points xs: [n, d] -> [n]."""
        return jnp.sum(jnp.abs(jnp.einsum("nij,nj->ni", self.A, xs)), axis=-1)

    def f(self, x):
        """Global objective at a single point x: [d]."""
        return jnp.mean(jnp.sum(jnp.abs(self.A @ x), axis=-1))

    def subgrad_i(self, i, x):
        Ai = self.A[i]
        return Ai.T @ paper_sign(Ai @ x)

    def subgrad_all(self, xs):
        """df_i(x_i) for per-worker points xs: [n, d] -> [n, d]."""
        y = jnp.einsum("nij,nj->ni", self.A, xs)
        return jnp.einsum("nij,ni->nj", self.A, paper_sign(y))

    def subgrad(self, x):
        """df(x) = (1/n) sum_i df_i(x) at a shared point x: [d]."""
        y = jnp.einsum("nij,j->ni", self.A, x)
        return jnp.mean(jnp.einsum("nij,ni->nj", self.A, paper_sign(y)), axis=0)

    @property
    def f_star(self) -> float:
        return 0.0

    @property
    def R0_sq(self) -> float:
        return float(jnp.sum(self.x0**2))


def _tridiag(d: int) -> np.ndarray:
    m = 2.0 * np.eye(d) - np.eye(d, k=1) - np.eye(d, k=-1)
    return m / 4.0


def generate_problem(
    *, n: int, d: int, noise_scale: float, seed: int = 0, mu: float = 1e-6
) -> L1Problem:
    """Algorithm 3 of the paper (synthetic dataset generation)."""
    rng = np.random.default_rng(seed)
    base = _tridiag(d)
    nus = 1.0 + noise_scale * rng.standard_normal(n)
    A = np.stack([nu * base for nu in nus])  # [n, d, d]
    Abar = A.mean(axis=0)
    lam_min = float(np.linalg.eigvalsh(Abar).min())
    A = A + (mu - lam_min) * np.eye(d)[None]
    x0 = rng.standard_normal(d)
    # spectral norms (symmetric => max |eig|); tridiagonal Toeplitz-like but
    # after shift no longer exactly Toeplitz — compute numerically.
    L0i = np.array([np.abs(np.linalg.eigvalsh(Ai)).max() for Ai in A])
    spec = np.array([np.linalg.norm(Ai, 2) for Ai in A])
    sigma_A = float(np.sqrt(max((spec**2).mean() - spec.mean() ** 2, 0.0)))
    return L1Problem(
        A=jnp.asarray(A, dtype=jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32),
        x0=jnp.asarray(x0, dtype=jnp.float32),
        L0i=jnp.asarray(L0i, dtype=jnp.float32),
        sigma_A=sigma_A,
    )


def sigma_A(A: np.ndarray) -> float:
    """Data dissimilarity measure, eq. (31)/(33)."""
    spec = np.array([np.linalg.norm(Ai, 2) for Ai in np.asarray(A)])
    return float(np.sqrt(max((spec**2).mean() - spec.mean() ** 2, 0.0)))
