"""SPMD realization of the federated rounds via ``shard_map``.

Workers live on a 1-D ``workers`` mesh axis (on the production mesh this is
the flattened (pod, data) axes — see launch/mesh.py). Each device owns
``local_n = n // axis_size`` workers: its slice of the A_i matrices and of the
per-worker shifts W. The server iterate x is replicated.

Key adaptation (DESIGN.md §2): the downlink messages Q_i(delta) are *not*
moved over the interconnect. The Bernoulli coin, the compressor key and the
replicated delta are shared, so every worker materializes its own message
locally (`zero-byte correlated broadcast`). The only real collectives are the
uplink ``psum`` of subgradients and scalars — exactly what the roofline
measures.

The module exposes:
  * :func:`make_marina_p_spmd_step` — Algorithm 2 as one jitted SPMD program;
  * :func:`make_ef21p_spmd_step`    — Algorithm 1 likewise;
  * both numerically equivalent to the single-process references in
    ef21p.py / marina_p.py (tested in tests/test_distributed.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

if hasattr(jax, "shard_map"):  # jax >= 0.6
    from jax import shard_map as _shard_map
else:  # jax 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-portable shard_map: old jax calls it ``check_rep``, very old
    jax supports neither kwarg — fall back by dropping it."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=check_vma)
    except TypeError:
        pass
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=check_vma)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

from .compressors import RandK, TopK
from .problems import paper_sign
from .stepsizes import Stepsize


class SpmdMarinaPState(NamedTuple):
    x: jax.Array  # [d] replicated
    W: jax.Array  # [n, d] sharded over workers
    t: jax.Array  # scalar


class SpmdEF21PState(NamedTuple):
    x: jax.Array  # [d] replicated
    w: jax.Array  # [d] replicated (synchronized shift)
    t: jax.Array


# ---------------------------------------------------------------------------
# helpers shared by both algorithms
# ---------------------------------------------------------------------------


def _local_subgrads(A_local, W_local):
    """df_i(w_i) = A_i^T sign(A_i w_i) for the local worker slice."""
    y = jnp.einsum("nij,nj->ni", A_local, W_local)
    g = jnp.einsum("nij,ni->nj", A_local, paper_sign(y))
    f = jnp.sum(jnp.abs(y), axis=-1)
    return g, f


def _randk_mask(key, d, k):
    idx = jax.random.choice(key, d, shape=(k,), replace=False)
    return jnp.zeros((d,)).at[idx].set(1.0)


# ---------------------------------------------------------------------------
# MARINA-P SPMD
# ---------------------------------------------------------------------------


def make_marina_p_spmd_step(
    mesh: Mesh,
    *,
    n: int,
    d: int,
    mode: str,
    k: int,
    p: float,
    stepsize: Stepsize,
    axis: str = "workers",
):
    """One SPMD MARINA-P round. A: [n,d,d] sharded over workers."""
    axis_size = mesh.shape[axis]
    assert n % axis_size == 0, (n, axis_size)
    local_n = n // axis_size

    def round_fn(x, W, t, A, key):
        # everything below runs per-shard; collectives are explicit psums.
        me = jax.lax.axis_index(axis)
        g_local, f_local = _local_subgrads(A, W)  # [local_n, d], [local_n]
        # ---- uplink: exact aggregation (the only real collective) ----------
        g = jax.lax.psum(jnp.sum(g_local, axis=0), axis) / n
        f_w = jax.lax.psum(jnp.sum(f_local), axis) / n
        g_sq_mean = jax.lax.psum(jnp.sum(jnp.sum(g_local**2, axis=-1)), axis) / n
        aux = {"f_w": f_w, "g_norm_sq": jnp.sum(g**2), "g_sq_mean": g_sq_mean}
        gamma = stepsize(t, aux)
        x_new = x - gamma * g
        delta = x_new - x
        # ---- downlink: materialized locally from shared randomness ---------
        k_bern, k_comp = jax.random.split(key)
        c = jax.random.bernoulli(k_bern, p)
        gids = me * local_n + jnp.arange(local_n)  # global worker ids
        if mode == "same":
            mask = _randk_mask(k_comp, d, k)
            Q = jnp.broadcast_to(mask * delta * (d / k), (local_n, d))
        elif mode == "ind":
            # per-worker keys via split, matching marina_p.make_broadcast
            # exactly (fold_in would give different masks than the reference)
            keys = jax.random.split(k_comp, n)

            def one(gid):
                return _randk_mask(keys[gid], d, k) * delta * (d / k)

            Q = jax.vmap(one)(gids)
        elif mode == "perm":
            q = d // n
            perm = jax.random.permutation(k_comp, d)

            def one(gid):
                block = jax.lax.dynamic_slice(perm, (gid * q,), (q,))
                m = jnp.zeros((d,)).at[block].set(1.0)
                rem = d - q * n
                if rem:
                    tail = jax.lax.dynamic_slice(perm, (q * n,), (rem,))
                    m = m + jnp.where(
                        gid == 0, jnp.zeros((d,)).at[tail].set(1.0), jnp.zeros((d,))
                    )
                return m * delta * n

            Q = jax.vmap(one)(gids)
        else:
            raise ValueError(mode)
        W_new = jnp.where(c, jnp.broadcast_to(x_new, W.shape), W + Q)
        metrics = {
            "f_w": f_w,
            "gamma": gamma,
            "full_sync": c.astype(jnp.float32),
            "q_nnz_mean": jax.lax.psum(
                jnp.sum(jnp.sum(Q != 0, axis=-1).astype(jnp.float32)), axis
            )
            / n,
        }
        return x_new, W_new, t + 1, metrics

    sharded = shard_map(
        round_fn,
        mesh=mesh,
        in_specs=(P(), P(axis), P(), P(axis), P()),
        out_specs=(P(), P(axis), P(), P()),
        check_vma=False,
    )
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# EF21-P SPMD
# ---------------------------------------------------------------------------


def make_ef21p_spmd_step(
    mesh: Mesh,
    *,
    n: int,
    d: int,
    k: int,
    stepsize: Stepsize,
    axis: str = "workers",
):
    """One SPMD EF21-P round with TopK downlink. A: [n,d,d] sharded."""
    axis_size = mesh.shape[axis]
    assert n % axis_size == 0
    comp = TopK(k=k)

    def round_fn(x, w, t, A):
        W = jnp.broadcast_to(w, (A.shape[0], d))
        g_local, f_local = _local_subgrads(A, W)
        g = jax.lax.psum(jnp.sum(g_local, axis=0), axis) / n
        f_w = jax.lax.psum(jnp.sum(f_local), axis) / n
        aux = {"f_w": f_w, "g_norm_sq": jnp.sum(g**2)}
        gamma = stepsize(t, aux)
        x_new = x - gamma * g
        # TopK is deterministic: server and every worker compute the same
        # delta from the replicated (x_new - w); zero downlink bytes on-mesh.
        delta = comp(None, x_new - w)
        w_new = w + delta
        metrics = {"f_w": f_w, "gamma": gamma,
                   "delta_nnz": jnp.sum(delta != 0).astype(jnp.float32)}
        return x_new, w_new, t + 1, metrics

    sharded = shard_map(
        round_fn,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(axis)),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )
    return jax.jit(sharded)


# ---------------------------------------------------------------------------
# convenience: place problem data on the mesh
# ---------------------------------------------------------------------------


def shard_problem(mesh: Mesh, A, axis: str = "workers"):
    sh = NamedSharding(mesh, P(axis))
    return jax.device_put(A, sh)
