"""Baseline distributed subgradient method SM (paper eq. (5)).

x^{t+1} = x^t - (gamma_t/n) sum_i df_i(x^t); the server broadcasts the full
x^{t+1} (dense downlink, 64*d bits/worker/round). This is the comparison
floor of Corollaries 1 & 2.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .comm_model import CommLedger, CommModel
from .problems import L1Problem
from .stepsizes import Stepsize


class SMState(NamedTuple):
    x: jax.Array
    t: jax.Array


def init(x0: jax.Array) -> SMState:
    return SMState(x=x0, t=jnp.zeros((), jnp.int32))


def make_step(problem: L1Problem, stepsize: Stepsize):
    def step(state: SMState, key):
        xs = jnp.broadcast_to(state.x, (problem.n, problem.d))
        g_all = problem.subgrad_all(xs)
        g = jnp.mean(g_all, axis=0)
        aux = {
            "f_w": problem.f(state.x),
            "g_norm_sq": jnp.sum(g**2),
            "g_sq_mean": jnp.mean(jnp.sum(g_all**2, axis=-1)),
        }
        gamma = stepsize(state.t, aux)
        x_new = state.x - gamma * g
        metrics = {"f_x": problem.f(x_new), "gamma": gamma}
        return SMState(x=x_new, t=state.t + 1), metrics

    return step


def run(
    problem: L1Problem,
    stepsize: Stepsize,
    *,
    T: Optional[int] = None,
    bit_budget: Optional[float] = None,
    seed: int = 0,
    record_every: int = 1,
):
    assert T is not None or bit_budget is not None
    ledger = CommLedger(model=CommModel(d=problem.d))
    step = jax.jit(make_step(problem, stepsize))
    state = init(problem.x0)
    key = jax.random.PRNGKey(seed)
    hist = {"t": [], "f_x": [], "gamma": [], "s2w_bits": []}
    t = 0
    while True:
        if T is not None and t >= T:
            break
        if bit_budget is not None and ledger.s2w_bits >= bit_budget:
            break
        key, sub = jax.random.split(key)
        state, m = step(state, sub)
        ledger.log_s2w_dense()
        ledger.tick()
        if t % record_every == 0:
            hist["t"].append(t)
            hist["f_x"].append(float(m["f_x"]))
            hist["gamma"].append(float(m["gamma"]))
            hist["s2w_bits"].append(ledger.s2w_bits)
        t += 1
    hist["final_state"] = state
    hist["ledger"] = ledger
    return hist
