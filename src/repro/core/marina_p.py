"""MARINA-P for non-smooth objectives (Algorithm 2).

Per round t:
    workers:  g_i = df_i(w_i^t)                  -> server   (uplink, exact)
    server:   gamma_t from schedule (constant / decreasing / Polyak (23))
              x^{t+1} = x^t - gamma_t * mean_i g_i
              c^t ~ Bernoulli(p)
              c=1: send x^{t+1} to all workers          (dense broadcast)
              c=0: send Q_i^t(x^{t+1} - x^t) to worker i (per-worker message)
    workers:  w_i^{t+1} = x^{t+1}  or  w_i^t + Q_i^t(x^{t+1} - x^t)

Three broadcast modes (Section 4.1):
  * ``same``: one RandK instance, identical message to every worker;
  * ``ind``:  independent RandK per worker (key folded with worker index);
  * ``perm``: PermK correlated family — (1/n) sum_i Q_i(x) = x exactly.

State is (x, W) with W = stack of worker shifts [n, d]. The Lyapunov function
of Theorem 2 is exposed for tests.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.obs.trace import maybe_attr, maybe_span

from .compressors import PermK, RandK, UnbiasedCompressor
from .comm_model import CommLedger, CommModel
from .problems import L1Problem
from .stepsizes import Stepsize, marina_p_lambda_star


class MarinaPState(NamedTuple):
    x: jax.Array  # server iterate [d]
    W: jax.Array  # worker shifts [n, d]
    t: jax.Array


def init(x0: jax.Array, n: int) -> MarinaPState:
    """w_i^0 = x^0 for all i (Algorithm 2, line 1)."""
    return MarinaPState(
        x=x0, W=jnp.broadcast_to(x0, (n, x0.shape[-1])), t=jnp.zeros((), jnp.int32)
    )


def lyapunov(
    state: MarinaPState,
    x_star: jax.Array,
    *,
    L0_bar: float,
    L0_tilde: float,
    omega: float,
    p: float,
) -> jax.Array:
    lam = marina_p_lambda_star(L0_bar, L0_tilde, omega, p)
    drift = jnp.mean(jnp.sum((state.W - state.x) ** 2, axis=-1))
    return jnp.sum((state.x - x_star) ** 2) + drift / (lam * p)


def make_broadcast(mode: str, n: int, k: int):
    """Return (fn(key, delta) -> Q of shape [n, d], omega(d))."""
    if mode == "same":
        comp = RandK(k=k)

        def bcast(key, delta):
            q = comp(key, delta)
            return jnp.broadcast_to(q, (n,) + delta.shape)

        return bcast, comp.omega
    if mode == "ind":
        comp = RandK(k=k)

        def bcast(key, delta):
            keys = jax.random.split(key, n)
            return jax.vmap(lambda kk: comp(kk, delta))(keys)

        return bcast, comp.omega
    if mode == "perm":
        def bcast(key, delta):
            d = delta.shape[-1]
            q = d // n
            perm = jax.random.permutation(key, d)
            # worker i keeps block i of the permutation, scaled by n
            def one(i):
                block = jax.lax.dynamic_slice(perm, (i * q,), (q,))
                return jnp.zeros_like(delta).at[block].set(1.0)

            masks = jax.vmap(one)(jnp.arange(n))
            rem = d - q * n
            if rem:
                tail = jax.lax.dynamic_slice(perm, (q * n,), (rem,))
                masks = masks.at[0].set(masks[0] + jnp.zeros_like(delta).at[tail].set(1.0))
            return masks * delta[None, :] * n

        return bcast, lambda d: float(n - 1)
    raise ValueError(f"unknown broadcast mode: {mode}")


def make_step(
    problem: L1Problem, mode: str, k: int, p: float, stepsize: Stepsize,
    *, return_q: bool = False, participation=None,
):
    """Build a jittable round: (state, key) -> (state, metrics).

    ``return_q=True`` additionally returns the per-worker messages Q [n, d]
    in the metrics so the host can serialize them (wire measurement path).

    ``participation`` (a :class:`repro.fleet.ParticipationPlan`) masks the
    uplink aggregation to the round's cohort: g, f_w and the Polyak aux
    terms become cohort means, while the downlink still addresses every
    worker (worker shifts must stay in sync for Algorithm 2's telescoping).
    The plan key is folded off the main stream (§8.5/§9.2 discipline), so
    the downlink RNG is bit-identical with and without a plan. An empty
    cohort yields g = 0 and f_w = 0, so Polyak's gap/(B·||g||²) form
    degrades to gamma = 0 (the iterate holds still) rather than NaN.
    """
    n = problem.n
    bcast, _ = make_broadcast(mode, n, k)
    plan = participation
    partial = plan is not None and not plan.is_full
    if partial:
        from repro.fleet.sampler import PARTICIPATION_FOLD

    def step(state: MarinaPState, key, force_sync=False):
        k_bern, k_comp = jax.random.split(key)
        # --- workers: subgradients at their own shifts -----------------------
        g_all = problem.subgrad_all(state.W)  # [n, d]
        f_all = problem.f_all(state.W)
        if partial:
            k_part = jax.random.fold_in(key, PARTICIPATION_FOLD)
            mask = plan.mask(k_part, n, state.t)
            wts = mask.astype(jnp.float32) / jnp.maximum(jnp.sum(mask), 1)
            g = jnp.tensordot(wts, g_all, axes=1)
            aux = {
                "f_w": jnp.sum(wts * f_all),
                "g_norm_sq": jnp.sum(g**2),
                "g_sq_mean": jnp.sum(wts * jnp.sum(g_all**2, axis=-1)),
            }
        else:
            g = jnp.mean(g_all, axis=0)
            aux = {
                "f_w": jnp.mean(f_all),
                "g_norm_sq": jnp.sum(g**2),
                "g_sq_mean": jnp.mean(jnp.sum(g_all**2, axis=-1)),
            }
        gamma = stepsize(state.t, aux)
        x_new = state.x - gamma * g
        # --- downlink ---------------------------------------------------------
        # force_sync promotes this round to the full-broadcast branch — the
        # transport layer's degraded-mode resync (DESIGN.md §8.4)
        c = jnp.logical_or(jax.random.bernoulli(k_bern, p), force_sync)
        Q = bcast(k_comp, x_new - state.x)  # [n, d]
        W_compressed = state.W + Q
        W_new = jnp.where(c, jnp.broadcast_to(x_new, state.W.shape), W_compressed)
        metrics = {
            "f_x": problem.f(x_new),
            "f_w": aux["f_w"],
            "gamma": gamma,
            "full_sync": c.astype(jnp.float32),
            "q_nnz_mean": jnp.mean(jnp.sum(Q != 0, axis=-1).astype(jnp.float32)),
            "drift": jnp.mean(jnp.sum((W_new - x_new) ** 2, axis=-1)),
        }
        if partial:
            metrics["participants"] = jnp.sum(mask).astype(jnp.float32)
        if return_q:
            metrics["Q"] = Q
            metrics["x_new"] = x_new
        return MarinaPState(x=x_new, W=W_new, t=state.t + 1), metrics

    return step


def run(
    problem: L1Problem,
    *,
    mode: str,
    k: int,
    p: float,
    stepsize: Stepsize,
    T: Optional[int] = None,
    bit_budget: Optional[float] = None,
    seed: int = 0,
    record_every: int = 1,
    measure_wire: bool = False,
    wire_mag: str = "fp32",
    device_encode: Optional[bool] = None,
    transport=None,
    tracker=None,
    participation=None,
):
    """Host loop; stops on T rounds or per-worker downlink bit budget.

    ``participation`` (a :class:`repro.fleet.ParticipationPlan`) restricts
    each round's uplink aggregation to the plan's cohort — see
    :func:`make_step`; ``hist["participants"]`` records cohort sizes.

    ``measure_wire=True`` additionally serializes every round's messages
    with the repro.wire codecs and tracks *measured* bits/worker next to a
    second analytic ledger whose value_bits is matched to the wire
    magnitude dtype (hist["wire_model_ledger"] — DESIGN.md §3.5). The
    primary ledger keeps the paper's 64-bit model, so ``bit_budget``
    semantics are identical with and without measurement.

    ``device_encode`` routes serialization through the fused Pallas encode
    kernels (kernels/encode.py) instead of the host numpy codec: True
    forces on, False forces off, None defers to ``REPRO_DEVICE_ENCODE`` /
    backend auto-detect (on for TPU). Buffers are byte-identical either
    way (DESIGN.md §11).

    ``transport`` (a :class:`repro.transport.Fleet` of per-worker links,
    or a :class:`repro.transport.FaultSpec` to build one) pushes every
    round's encoded messages through fault-injected reliable links
    (DESIGN.md §8.4): a worker whose frame cannot be delivered keeps its
    stale shift for the round (its W row is rolled back), and any link
    flagging ``resync_needed`` promotes the *next* round to the full sync
    broadcast (``force_sync``), whose self-contained SYNC frame repairs
    the receiver. Degraded rounds are charged dense bits by the ledger
    exactly like organic ``p``-coin syncs. ``hist["transport"]`` carries
    the fleet counters (retries, resyncs, goodput, recovery latency).

    Uplink is exact (Algorithm 2: workers send raw subgradients), so the
    ledger also accrues one dense w2s message per round
    (hist["w2s_bits"]). ``tracker`` (a :class:`repro.obs.Tracker`)
    receives the recorded rounds as step-indexed metric events.
    """
    assert T is not None or bit_budget is not None
    need_q = measure_wire or transport is not None
    wire_model_ledger = None
    fleet = None
    use_dev = False
    if need_q:
        import numpy as np

        from repro import wire
        from repro.kernels import encode as kenc

        # Fused on-device encode (kernels/encode.py): the Q rows / x_new are
        # already jax arrays here, so when enabled the packed buffers come
        # straight off the device — byte-identical to the host codec.
        use_dev = kenc.device_encode_enabled(device_encode)

        def enc_dense(v):
            if use_dev:
                return kenc.dense_encode(v, mag=wire_mag)
            return wire.encode_dense(np.asarray(v), mag=wire_mag)

        def enc_q_rows(Q):
            if use_dev:
                return kenc.encode_rows(Q, mag=wire_mag)
            Qh = np.asarray(Q)
            return [wire.encode_sparse(Qh[i], mag=wire_mag)
                    for i in range(Qh.shape[0])]
    if measure_wire:
        wire_model_ledger = CommLedger(
            model=CommModel(d=problem.d, value_bits=wire.MAG_BITS[wire.mag_dtype(wire_mag)])
        )
    if transport is not None:
        from repro.transport import FaultSpec, Fleet

        fleet = (
            Fleet.make(problem.n, transport, timeout=2, max_retries=2)
            if isinstance(transport, FaultSpec)
            else transport
        )
        assert len(fleet) == problem.n, (len(fleet), problem.n)
        if tracker is not None:
            fleet.attach_tracker(tracker)  # link/* spans nest under rounds
    cm = CommModel(d=problem.d)
    ledger = CommLedger(model=cm)
    step = jax.jit(make_step(problem, mode, k, p, stepsize, return_q=need_q,
                             participation=participation))
    state = init(problem.x0, problem.n)
    key = jax.random.PRNGKey(seed)
    hist = {"t": [], "f_x": [], "f_w": [], "gamma": [], "s2w_bits": [],
            "w2s_bits": [], "drift": []}
    partial = participation is not None and not participation.is_full
    if partial:
        hist["participants"] = []
    if measure_wire:
        hist["wire_bits"] = []
    wire_total = 0.0
    force_sync = False
    t = 0
    while True:
        if T is not None and t >= T:
            break
        if bit_budget is not None and ledger.s2w_bits >= bit_budget:
            break
        key, sub = jax.random.split(key)
        prev_W = state.W
        was_forced = force_sync
        # §10 trace: one "round" span per iteration; the jitted step
        # (subgrad + stepsize + compress, fused) is charged to "subgrad",
        # the host read of gamma to "stepsize", and the transport section
        # to "broadcast" with encode + per-worker link/* children.
        with maybe_span(tracker, "round", round=t, alg="marina_p") as rsp:
            with maybe_span(tracker, "subgrad",
                            fused="subgrad+stepsize+compress"):
                state, m = step(state, sub, force_sync)
                if tracker is not None:
                    jax.block_until_ready(m["f_x"])
            force_sync = False
            with maybe_span(tracker, "stepsize") as ssp:
                gamma = float(m["gamma"])
                maybe_attr(ssp, gamma=gamma)
            full_sync = float(m["full_sync"]) > 0
            maybe_attr(rsp, full_sync=full_sync, force_sync=was_forced,
                       gamma=gamma)
            if fleet is not None:
                with maybe_span(tracker, "broadcast",
                                full_sync=full_sync) as bsp:
                    with maybe_span(tracker, "encode", device=use_dev):
                        if full_sync:
                            payloads = [enc_dense(m["x_new"])]
                        else:
                            payloads = enc_q_rows(m["Q"])
                    if full_sync:
                        oks = fleet.broadcast(payloads[0], sync=True)
                    else:
                        oks = fleet.send_per_worker(payloads)
                    if not all(oks):  # undelivered workers keep stale shifts
                        mask = jnp.asarray(oks)[:, None]
                        state = state._replace(W=jnp.where(mask, state.W, prev_W))
                    fleet.drain()
                    force_sync = fleet.resync_needed or not all(oks)
                    maybe_attr(bsp, delivered=int(sum(oks)),
                               resync_next=force_sync)
        if full_sync:
            ledger.log_s2w_dense()
        else:
            ledger.log_s2w_sparse(float(m["q_nnz_mean"]))
        ledger.log_w2s_dense()  # uplink: exact subgradient every round
        ledger.tick()
        if measure_wire:
            if full_sync:
                wire_model_ledger.log_s2w_dense()
                wire_total += wire.measured_bits(enc_dense(m["x_new"]))
            else:
                wire_model_ledger.log_s2w_sparse(float(m["q_nnz_mean"]))
                if mode == "same":  # all rows identical: one encode suffices
                    if use_dev:
                        buf = kenc.sparse_encode(m["Q"][0], mag=wire_mag)
                    else:
                        buf = wire.encode_sparse(
                            np.asarray(m["Q"][0]), mag=wire_mag)
                    wire_total += wire.measured_bits(buf)
                else:
                    bufs = enc_q_rows(m["Q"])
                    wire_total += sum(
                        wire.measured_bits(b) for b in bufs
                    ) / len(bufs)
            wire_model_ledger.tick()
        if t % record_every == 0:
            hist["t"].append(t)
            hist["f_x"].append(float(m["f_x"]))
            hist["f_w"].append(float(m["f_w"]))
            hist["gamma"].append(gamma)
            hist["drift"].append(float(m["drift"]))
            hist["s2w_bits"].append(ledger.s2w_bits)
            hist["w2s_bits"].append(ledger.w2s_bits)
            if partial:
                hist["participants"].append(float(m["participants"]))
            if measure_wire:
                hist["wire_bits"].append(wire_total)
            if tracker is not None:
                rec = {
                    "marina_p/f_x": hist["f_x"][-1],
                    "marina_p/f_w": hist["f_w"][-1],
                    "marina_p/gamma": hist["gamma"][-1],
                    "marina_p/drift": hist["drift"][-1],
                    "marina_p/s2w_bits": ledger.s2w_bits,
                    "marina_p/w2s_bits": ledger.w2s_bits,
                    "marina_p/full_sync": full_sync,
                }
                if partial:
                    rec["marina_p/participants"] = hist["participants"][-1]
                if measure_wire:
                    rec["marina_p/wire_bits"] = wire_total
                tracker.log(rec, step=t)
        t += 1
    hist["final_state"] = state
    hist["ledger"] = ledger
    if measure_wire:
        hist["wire_bits_total"] = wire_total
        hist["wire_model_ledger"] = wire_model_ledger
    if fleet is not None:
        stats = fleet.stats()
        hist["transport"] = stats.as_metrics()
        hist["transport_stats"] = stats
        if tracker is not None:
            fleet.log_to(tracker, step=t)
    return hist
