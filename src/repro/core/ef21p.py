"""EF21-P, distributed version (Algorithm 1; single-node Algorithm 4).

Per round t:
    workers:  g_i = df_i(w^t)            -> server        (uplink, exact)
    server:   gamma_t from schedule      (constant / decreasing / Polyak (13))
              x^{t+1} = x^t - gamma_t * mean_i g_i
              Delta = C(x^{t+1} - w^t)   -> all workers    (downlink, compressed)
              w^{t+1} = w^t + Delta      (identical on server & workers)

The worker/server ``w`` states stay synchronized by construction, so the
state is just (x, w). The Lyapunov function of Theorem 1 is exposed for tests:
V^t = ||x-x*||^2 + (1/(lambda* theta)) ||w-x||^2.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.obs.trace import maybe_attr, maybe_span

from .compressors import ContractiveCompressor
from .comm_model import CommLedger, CommModel
from .problems import L1Problem
from .stepsizes import Stepsize, ef21p_B_star, ef21p_lambda_star


class EF21PState(NamedTuple):
    x: jax.Array  # server iterate [d]
    w: jax.Array  # synchronized shift [d]
    t: jax.Array  # round counter


def init(x0: jax.Array) -> EF21PState:
    """w^0 = x^0 (Algorithm 1, line 1)."""
    return EF21PState(x=x0, w=x0, t=jnp.zeros((), jnp.int32))


def lyapunov(state: EF21PState, x_star: jax.Array, alpha: float) -> jax.Array:
    lam = ef21p_lambda_star(alpha)
    theta = 1.0 - (1.0 - alpha) ** 0.5
    return jnp.sum((state.x - x_star) ** 2) + jnp.sum((state.w - state.x) ** 2) / (
        lam * theta
    )


def make_step(problem: L1Problem, comp: ContractiveCompressor, stepsize: Stepsize,
              *, return_delta: bool = False, participation=None):
    """Build a jittable round function (state, key) -> (state, metrics).

    ``return_delta=True`` additionally returns the broadcast message
    (the compressed difference) so the host can serialize it (wire
    measurement path).

    ``participation`` (a :class:`repro.fleet.ParticipationPlan`) masks the
    uplink aggregation to the round's cohort; the shift broadcast still
    addresses everyone (w stays synchronized by construction). The plan
    key is folded off the main stream (§8.5/§9.2), keeping the compressor
    RNG bit-identical with and without a plan; an empty cohort gives
    g = 0 and f_w = 0, so Polyak's (13) degrades to gamma = 0, not NaN."""
    plan = participation
    partial = plan is not None and not plan.is_full
    if partial:
        from repro.fleet.sampler import PARTICIPATION_FOLD

    def step(state: EF21PState, key, force_sync=False):
        # --- workers: subgradients at the shared shift w^t ------------------
        w_stack = jnp.broadcast_to(state.w, (problem.n, problem.d))
        g_all = problem.subgrad_all(w_stack)  # [n, d]
        f_all = problem.f_all(w_stack)
        # --- server: stepsize (Polyak needs f(w^t) and ||g||^2) -------------
        if partial:
            k_part = jax.random.fold_in(key, PARTICIPATION_FOLD)
            mask = plan.mask(k_part, problem.n, state.t)
            wts = mask.astype(jnp.float32) / jnp.maximum(jnp.sum(mask), 1)
            g = jnp.tensordot(wts, g_all, axes=1)
            aux = {"f_w": jnp.sum(wts * f_all), "g_norm_sq": jnp.sum(g**2)}
        else:
            g = jnp.mean(g_all, axis=0)
            aux = {"f_w": jnp.mean(f_all), "g_norm_sq": jnp.sum(g**2)}
        gamma = stepsize(state.t, aux)
        x_new = state.x - gamma * g
        # --- downlink: compressed difference ---------------------------------
        # force_sync re-anchors the shift with a dense w := x broadcast — the
        # transport layer's degraded-mode recovery (DESIGN.md §8.4)
        delta = jnp.where(force_sync, x_new - state.w, comp(key, x_new - state.w))
        w_new = state.w + delta
        metrics = {
            "f_x": problem.f(x_new),
            "f_w": aux["f_w"],
            "gamma": gamma,
            "delta_nnz": jnp.sum(delta != 0).astype(jnp.float32),
            "full_sync": jnp.asarray(force_sync, jnp.float32),
        }
        if partial:
            metrics["participants"] = jnp.sum(mask).astype(jnp.float32)
        if return_delta:
            metrics["delta"] = delta
        return EF21PState(x=x_new, w=w_new, t=state.t + 1), metrics

    return step


def run(
    problem: L1Problem,
    comp: ContractiveCompressor,
    stepsize: Stepsize,
    *,
    T: Optional[int] = None,
    bit_budget: Optional[float] = None,
    seed: int = 0,
    record_every: int = 1,
    measure_wire: bool = False,
    wire_mag: str = "fp32",
    device_encode: Optional[bool] = None,
    transport=None,
    tracker=None,
    participation=None,
):
    """Host loop driving the jitted round; returns history dict.

    ``participation`` (a :class:`repro.fleet.ParticipationPlan`) restricts
    each round's uplink aggregation to the plan's cohort — see
    :func:`make_step`; ``hist["participants"]`` records cohort sizes.

    Stops after T rounds or when the per-worker downlink ``bit_budget``
    (paper App. A communication budgets) is exhausted. ``measure_wire=True``
    serializes each broadcast with the repro.wire sparse codec and tracks
    measured bits next to a second analytic ledger whose value_bits is
    matched to the wire magnitude dtype (hist["wire_model_ledger"] —
    DESIGN.md §3.5); the primary ledger keeps the paper's 64-bit model so
    ``bit_budget`` semantics do not change under measurement.

    ``device_encode`` routes serialization through the fused Pallas encode
    kernels (kernels/encode.py): True forces on, False forces off, None
    defers to ``REPRO_DEVICE_ENCODE`` / backend auto-detect (on for TPU).
    Buffers are byte-identical either way (DESIGN.md §11).

    ``transport`` (a :class:`repro.transport.Fleet`, or a
    :class:`repro.transport.FaultSpec` to build one) pushes each round's
    broadcast through fault-injected reliable links. EF21-P's shift must
    stay synchronized across server and workers, so the commit is
    two-phase (DESIGN.md §8.4): if any worker misses the broadcast, the
    server rolls its shift back (``w`` unchanged — the round still
    advances ``x``) and the next round re-anchors with a dense
    ``w := x`` SYNC broadcast, charged dense bits by the ledger.
    ``hist["transport"]`` carries the fleet counters.

    Uplink is exact (Algorithm 1), so the ledger also accrues one dense
    w2s message per round (hist["w2s_bits"]). ``tracker`` (a
    :class:`repro.obs.Tracker`) receives the recorded rounds as
    step-indexed metric events.
    """
    assert T is not None or bit_budget is not None
    need_delta = measure_wire or transport is not None
    wire_model_ledger = None
    fleet = None
    use_dev = False
    if need_delta:
        import numpy as np

        from repro import wire
        from repro.kernels import encode as kenc

        # Fused on-device encode (kernels/encode.py, DESIGN.md §11):
        # delta / w are jax arrays here, so the packed buffer comes straight
        # off the device — byte-identical to the host codec either way.
        use_dev = kenc.device_encode_enabled(device_encode)

        def enc_dense(v):
            if use_dev:
                return kenc.dense_encode(v, mag=wire_mag)
            return wire.encode_dense(np.asarray(v), mag=wire_mag)

        def enc_sparse(v):
            if use_dev:
                return kenc.sparse_encode(v, mag=wire_mag)
            return wire.encode_sparse(np.asarray(v), mag=wire_mag)
    if measure_wire:
        wire_model_ledger = CommLedger(
            model=CommModel(d=problem.d, value_bits=wire.MAG_BITS[wire.mag_dtype(wire_mag)])
        )
    if transport is not None:
        from repro.transport import FaultSpec, Fleet

        fleet = (
            Fleet.make(problem.n, transport, timeout=2, max_retries=2)
            if isinstance(transport, FaultSpec)
            else transport
        )
        assert len(fleet) == problem.n, (len(fleet), problem.n)
        if tracker is not None:
            fleet.attach_tracker(tracker)
    cm = CommModel(d=problem.d)
    ledger = CommLedger(model=cm)
    step = jax.jit(make_step(problem, comp, stepsize, return_delta=need_delta,
                             participation=participation))
    state = init(problem.x0)
    key = jax.random.PRNGKey(seed)
    hist = {"t": [], "f_x": [], "f_w": [], "gamma": [], "s2w_bits": [],
            "w2s_bits": []}
    partial = participation is not None and not participation.is_full
    if partial:
        hist["participants"] = []
    if measure_wire:
        hist["wire_bits"] = []
    wire_total = 0.0
    force_sync = False
    t = 0
    while True:
        if T is not None and t >= T:
            break
        if bit_budget is not None and ledger.s2w_bits >= bit_budget:
            break
        key, sub = jax.random.split(key)
        prev_w = state.w
        with maybe_span(tracker, "round", round=t, alg="ef21p") as rsp:
            with maybe_span(tracker, "subgrad", fused="subgrad+stepsize+compress"):
                state, m = step(state, sub, force_sync)
                if tracker is not None:
                    jax.block_until_ready(m["f_x"])
            synced = force_sync
            force_sync = False
            with maybe_span(tracker, "stepsize") as ssp:
                gamma = float(m["gamma"])
                maybe_attr(ssp, gamma=gamma)
            maybe_attr(rsp, full_sync=synced, force_sync=synced, gamma=gamma)
            if fleet is not None:
                with maybe_span(tracker, "broadcast", full_sync=synced) as bsp:
                    with maybe_span(tracker, "encode", device=use_dev):
                        if synced:  # self-contained re-anchor: the full new shift
                            payload = enc_dense(state.w)
                        else:
                            payload = enc_sparse(m["delta"])
                    oks = fleet.broadcast(payload, sync=synced)
                    fleet.drain()
                    if not all(oks) or fleet.resync_needed:
                        # two-phase commit: some worker is stale — keep the
                        # server shift at w^t and repair next round with a
                        # dense re-anchor
                        state = state._replace(w=prev_w)
                        force_sync = True
                    maybe_attr(bsp, delivered=int(sum(oks)),
                               resync_next=force_sync)
        if synced:
            ledger.log_s2w_dense()
        else:
            ledger.log_s2w_sparse(float(m["delta_nnz"]))
        ledger.log_w2s_dense()  # uplink: exact subgradient every round
        ledger.tick()
        if measure_wire:
            if synced:
                wire_model_ledger.log_s2w_dense()
            else:
                wire_model_ledger.log_s2w_sparse(float(m["delta_nnz"]))
            wire_model_ledger.tick()
            wire_total += wire.measured_bits(
                enc_dense(m["delta"]) if synced else enc_sparse(m["delta"])
            )
        if t % record_every == 0:
            hist["t"].append(t)
            hist["f_x"].append(float(m["f_x"]))
            hist["f_w"].append(float(m["f_w"]))
            hist["gamma"].append(gamma)
            hist["s2w_bits"].append(ledger.s2w_bits)
            hist["w2s_bits"].append(ledger.w2s_bits)
            if partial:
                hist["participants"].append(float(m["participants"]))
            if measure_wire:
                hist["wire_bits"].append(wire_total)
            if tracker is not None:
                rec = {
                    "ef21p/f_x": hist["f_x"][-1],
                    "ef21p/f_w": hist["f_w"][-1],
                    "ef21p/gamma": hist["gamma"][-1],
                    "ef21p/s2w_bits": ledger.s2w_bits,
                    "ef21p/w2s_bits": ledger.w2s_bits,
                }
                if partial:
                    rec["ef21p/participants"] = hist["participants"][-1]
                if measure_wire:
                    rec["ef21p/wire_bits"] = wire_total
                tracker.log(rec, step=t)
        t += 1
    hist["final_state"] = state
    hist["ledger"] = ledger
    if measure_wire:
        hist["wire_bits_total"] = wire_total
        hist["wire_model_ledger"] = wire_model_ledger
    if fleet is not None:
        stats = fleet.stats()
        hist["transport"] = stats.as_metrics()
        hist["transport_stats"] = stats
        if tracker is not None:
            fleet.log_to(tracker, step=t)
    return hist
