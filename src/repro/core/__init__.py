"""Core library: the paper's contribution as composable JAX modules.

* compressors — Definitions 2/3 operator families (TopK, RandK, PermK, ...)
* stepsizes   — constant / decreasing / Polyak schedules + theory constants
* ef21p       — distributed EF21-P (Algorithm 1)
* marina_p    — non-smooth MARINA-P (Algorithm 2), three broadcast modes
* subgradient — baseline distributed SM (eq. 5)
* problems    — the paper's L1 workload + Algorithm 3 datagen
* comm_model  — Definition 1/4 bit accounting + Corollary 1/2 predictions
* distributed — shard_map SPMD realization of both algorithms
"""
from . import comm_model, compressors, distributed, ef21p, marina_p, problems, stepsizes, subgradient  # noqa: F401
