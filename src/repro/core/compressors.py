"""Compression operators (Definitions 2 & 3 of the paper).

Two families:

* Unbiased ``Q in U(omega)``:  E[Q(x)] = x,  E||Q(x)-x||^2 <= omega ||x||^2.
  Members: RandK (omega = d/K - 1), PermK (omega = n - 1), natural
  compression (omega = 1/8), identity (omega = 0).
* Contractive ``C in B(alpha)``:  E||C(x)-x||^2 <= (1-alpha) ||x||^2.
  Members: TopK (alpha = K/d), block-TopK (alpha = K_b/b per block — the
  TPU-native variant, see DESIGN.md §2), and any scaled unbiased compressor
  ``(omega+1)^{-1} Q in B((omega+1)^{-1})`` (Lemma 8 of Richtarik et al. 2021).

All operators are stateless: randomness comes from an explicit ``jax.random``
key, so the same key on server and worker materializes the same sparse message
without moving indices over the wire (the zero-byte correlated broadcast trick
from DESIGN.md §2). Operators act on flat vectors; :func:`tree_compress`
lifts them to parameter pytrees via ravel/unravel.

Expected density ``zeta`` (Definition 4) is exposed per operator for the
communication model.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np


Array = jax.Array


# ---------------------------------------------------------------------------
# Base classes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A (possibly randomized) mapping R^d -> R^d.

    Subclasses implement :meth:`__call__`. ``needs_key`` tells callers
    whether the operator consumes randomness.
    """

    name: str = dataclasses.field(default="compressor", init=False)

    def __call__(self, key: Optional[Array], x: Array) -> Array:  # pragma: no cover
        raise NotImplementedError

    # -- communication accounting -------------------------------------------------
    def expected_density(self, d: int) -> float:
        """zeta: expected number of non-zeros sent per message (Definition 4)."""
        raise NotImplementedError

    # -- theory constants -----------------------------------------------------------
    @property
    def needs_key(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class UnbiasedCompressor(Compressor):
    """Q in U(omega): E[Q(x)] = x and E||Q(x)-x||^2 <= omega ||x||^2."""

    def omega(self, d: int) -> float:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ContractiveCompressor(Compressor):
    """C in B(alpha): E||C(x)-x||^2 <= (1-alpha) ||x||^2."""

    def alpha(self, d: int) -> float:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Identity
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Identity(UnbiasedCompressor, ContractiveCompressor):
    name: str = dataclasses.field(default="identity", init=False)

    def __call__(self, key, x):
        return x

    def omega(self, d):
        return 0.0

    def alpha(self, d):
        return 1.0

    def expected_density(self, d):
        return float(d)

    @property
    def needs_key(self):
        return False


# ---------------------------------------------------------------------------
# TopK (contractive, Definition 3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopK(ContractiveCompressor):
    """Global magnitude Top-K: keep the K largest-|.| coordinates.

    Deterministic; alpha = K/d.
    """

    k: int = 1
    name: str = dataclasses.field(default="topk", init=False)

    def __call__(self, key, x):
        d = x.shape[-1]
        k = min(self.k, d)
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        mask = jnp.zeros_like(x).at[idx].set(1.0)
        return x * mask

    def alpha(self, d):
        return min(self.k, d) / d

    def expected_density(self, d):
        return float(min(self.k, d))

    @property
    def needs_key(self):
        return False


@dataclasses.dataclass(frozen=True)
class BlockTopK(ContractiveCompressor):
    """TPU-native block-local TopK: top-k_b per contiguous block of size b.

    Contractive with alpha = k_b/b (per-block contraction implies global).
    Total kept = k_b * ceil(d/b). This is the semantics the Pallas kernel
    (kernels/topk.py) implements on 8x128 VMEM tiles.
    """

    k_per_block: int = 16
    block: int = 1024
    name: str = dataclasses.field(default="block_topk", init=False)

    def __call__(self, key, x):
        d = x.shape[-1]
        b = self.block
        pad = (-d) % b
        xp = jnp.pad(x, (0, pad))
        xb = xp.reshape(-1, b)
        k = min(self.k_per_block, b)
        _, idx = jax.lax.top_k(jnp.abs(xb), k)
        mask = jnp.zeros_like(xb)
        mask = jax.vmap(lambda m, i: m.at[i].set(1.0))(mask, idx)
        out = (xb * mask).reshape(-1)[:d]
        return out

    def alpha(self, d):
        return min(self.k_per_block, self.block) / self.block

    def expected_density(self, d):
        nblocks = -(-d // self.block)
        return float(min(self.k_per_block, self.block) * nblocks)

    @property
    def needs_key(self):
        return False


# ---------------------------------------------------------------------------
# RandK (unbiased, Definition 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RandK(UnbiasedCompressor):
    """Uniform random-K sparsification with (d/K) rescaling.

    E[Q(x)] = x; omega = d/K - 1. A shared key across workers gives the
    paper's ``sameRandK``; per-worker folded keys give ``indRandK``.
    """

    k: int = 1
    name: str = dataclasses.field(default="randk", init=False)

    def __call__(self, key, x):
        d = x.shape[-1]
        k = min(self.k, d)
        idx = jax.random.choice(key, d, shape=(k,), replace=False)
        mask = jnp.zeros_like(x).at[idx].set(1.0)
        return x * mask * (d / k)

    def omega(self, d):
        k = min(self.k, d)
        return d / k - 1.0

    def expected_density(self, d):
        return float(min(self.k, d))


# ---------------------------------------------------------------------------
# PermK (correlated unbiased, Definition 5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PermK(UnbiasedCompressor):
    """Permutation compressor for worker ``i`` of ``n`` (Definition 5).

    Requires d = q*n (handled by padding in tree_compress when needed).
    Q_i(x) = n * sum_{j in block i of a shared random permutation} x_j e_j.
    Across workers with the same key: (1/n) sum_i Q_i(x) = x exactly.
    omega = n - 1.
    """

    n: int = 1
    worker: int = 0
    name: str = dataclasses.field(default="permk", init=False)

    def __call__(self, key, x):
        d = x.shape[-1]
        q = d // self.n
        perm = jax.random.permutation(key, d)
        block = jax.lax.dynamic_slice(perm, (self.worker * q,), (q,))
        mask = jnp.zeros_like(x).at[block].set(1.0)
        out = x * mask * self.n
        # leftover coordinates (d not divisible by n) are assigned to worker 0
        rem = d - q * self.n
        if rem:
            tail = jax.lax.dynamic_slice(perm, (q * self.n,), (rem,))
            tmask = jnp.zeros_like(x).at[tail].set(1.0)
            out = jnp.where(self.worker == 0, out + x * tmask * self.n, out)
        return out

    def omega(self, d):
        return self.n - 1.0

    def expected_density(self, d):
        return float(-(-d // self.n))


def permk_family(n: int) -> list[PermK]:
    """The n correlated compressors {Q_i} of Definition 5."""
    return [PermK(n=n, worker=i) for i in range(n)]


# ---------------------------------------------------------------------------
# LM-scale jit-friendly variants (hardware adaptation, DESIGN.md §2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RotK(UnbiasedCompressor):
    """TPU-native PermK: cyclic coordinate partition with a random rotation.

    Worker ``i`` of ``n`` keeps coordinates ``j`` with
    ``j mod n == (i + r) mod n`` where ``r ~ Uniform{0..n-1}`` is shared,
    scaled by ``n``. Properties (proved in tests/test_compressors.py):

    * exact partition:  (1/n) sum_i Q_i(x) = x  (PermK's key identity);
    * unbiased with omega = n - 1 (same as PermK): each coordinate is kept
      w.p. 1/n over the rotation, scaled by n;
    * zero index storage / O(1) mask materialization (iota + compare) — no
      d-sized scatter, so it scales to billions of parameters per leaf.

    vs. Definition 5's PermK: the partition is block-cyclic instead of a
    uniformly random permutation. The variance bound is identical; only the
    coordinate-correlation structure differs (documented in DESIGN.md §2).
    """

    n: int = 1
    worker: int = 0
    name: str = dataclasses.field(default="rotk", init=False)

    def __call__(self, key, x):
        d = x.shape[-1]
        r = jax.random.randint(key, (), 0, self.n)
        idx = jax.lax.iota(jnp.int32, d) % self.n
        mask = (idx == (self.worker + r) % self.n).astype(x.dtype)
        return x * mask * self.n

    def mask_for(self, key, d, worker):
        """Mask for a dynamic (traced) worker index — used by vmapped LM code."""
        r = jax.random.randint(key, (), 0, self.n)
        idx = jax.lax.iota(jnp.int32, d) % self.n
        return (idx == (worker + r) % self.n)

    def omega(self, d):
        return self.n - 1.0

    def expected_density(self, d):
        return float(-(-d // self.n))


@dataclasses.dataclass(frozen=True)
class BernK(UnbiasedCompressor):
    """Bernoulli sparsification: keep each coordinate w.p. q = k/d, scale 1/q.

    Unbiased with omega = d/k - 1 (identical to RandK) and expected density
    k, but mask materialization is a single uniform-compare — no
    no-replacement choice / scatter, so it scales to LM-sized leaves. This is
    the jit-friendly stand-in for indRandK/sameRandK at LM scale.
    """

    k: int = 1
    name: str = dataclasses.field(default="bernk", init=False)

    def __call__(self, key, x):
        d = x.shape[-1]
        q = min(self.k, d) / d
        mask = (jax.random.uniform(key, x.shape) < q).astype(x.dtype)
        return x * mask / q

    def omega(self, d):
        k = min(self.k, d)
        return d / k - 1.0

    def expected_density(self, d):
        return float(min(self.k, d))


# ---------------------------------------------------------------------------
# Natural compression (unbiased, omega = 1/8)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NaturalCompression(UnbiasedCompressor):
    """Stochastic rounding of mantissa to powers of two (Horvath et al. 2022).

    For x != 0: round to 2^floor(log2|x|) or 2^ceil(log2|x|) with
    probabilities making it unbiased; omega = 1/8. Dense (zeta = d) but each
    float costs only 9 bits (sign + exponent).
    """

    name: str = dataclasses.field(default="natural", init=False)
    bits_per_value: int = 9

    def __call__(self, key, x):
        ax = jnp.abs(x)
        lo_exp = jnp.floor(jnp.log2(jnp.where(ax > 0, ax, 1.0)))
        lo = jnp.exp2(lo_exp)
        hi = lo * 2.0
        # p(hi) chosen so expectation is exact: ax = p*hi + (1-p)*lo
        p_hi = jnp.where(ax > 0, (ax - lo) / (hi - lo), 0.0)
        u = jax.random.uniform(key, x.shape)
        mag = jnp.where(u < p_hi, hi, lo)
        return jnp.where(ax > 0, jnp.sign(x) * mag, 0.0)

    def omega(self, d):
        return 0.125

    def expected_density(self, d):
        return float(d)


# ---------------------------------------------------------------------------
# Scaled unbiased -> contractive (Lemma 8, Richtarik et al. 2021)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScaledUnbiased(ContractiveCompressor):
    """(omega+1)^{-1} Q in B((omega+1)^{-1}) for Q in U(omega)."""

    inner: UnbiasedCompressor = dataclasses.field(default_factory=lambda: RandK(k=1))
    d_hint: int = 1
    name: str = dataclasses.field(default="scaled_unbiased", init=False)

    def __call__(self, key, x):
        w = self.inner.omega(x.shape[-1])
        return self.inner(key, x) / (w + 1.0)

    def alpha(self, d):
        return 1.0 / (self.inner.omega(d) + 1.0)

    def expected_density(self, d):
        return self.inner.expected_density(d)

    @property
    def needs_key(self):
        return self.inner.needs_key


# ---------------------------------------------------------------------------
# Pytree lifting
# ---------------------------------------------------------------------------


def tree_ravel(tree):
    flat, unravel = jax.flatten_util.ravel_pytree(tree)
    return flat, unravel


def tree_compress(comp: Compressor, key: Optional[Array], tree):
    """Apply a flat-vector compressor to a parameter pytree."""
    flat, unravel = jax.flatten_util.ravel_pytree(tree)
    out = comp(key, flat)
    return unravel(out)


# registry used by configs / CLI ------------------------------------------------

def make_compressor(spec: str, *, d: int, n: int = 1, worker: int = 0) -> Compressor:
    """Parse a compressor spec string, e.g. ``topk:32``, ``randk:32``,
    ``permk``, ``block_topk:16:1024``, ``natural``, ``identity``."""
    parts = spec.split(":")
    kind = parts[0]
    if kind == "identity":
        return Identity()
    if kind == "topk":
        return TopK(k=int(parts[1]) if len(parts) > 1 else max(1, d // n))
    if kind == "block_topk":
        kb = int(parts[1]) if len(parts) > 1 else 16
        b = int(parts[2]) if len(parts) > 2 else 1024
        return BlockTopK(k_per_block=kb, block=b)
    if kind == "randk":
        return RandK(k=int(parts[1]) if len(parts) > 1 else max(1, d // n))
    if kind == "permk":
        return PermK(n=n, worker=worker)
    if kind == "natural":
        return NaturalCompression()
    raise ValueError(f"unknown compressor spec: {spec}")
