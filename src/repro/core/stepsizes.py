"""Stepsize schedules of Theorems 1 & 2 (constant / decreasing / Polyak).

Every schedule is a pure function ``gamma_t = schedule(state) -> (gamma, state)``
so it can live inside a jitted training step. The Polyak stepsizes (13)/(23)
consume quantities the algorithms already communicate (Remark 1): the averaged
function values and subgradients.

Formulas (paper equation numbers in brackets):

* EF21-P constant-optimal  (11):  gamma = sqrt(V0 / (B* L0^2)) / sqrt(T)
* EF21-P Polyak            (13):  gamma_t = (f(w^t) - f*) / (B* ||df(w^t)||^2)
* decreasing               (15):  gamma_t = gamma0 / sqrt(t+1)
* EF21-P decreasing-opt    (17):  gamma0 = sqrt(V0 / (2 B* L0^2 log(T+1)))
* MARINA-P constant-opt    (21):  gamma = sqrt(V0 / Btil*) / sqrt(T)
* MARINA-P Polyak          (23):  see :func:`marina_p_polyak`
* MARINA-P decreasing-opt  (27):  gamma0 = sqrt(V0 / (2 Btil* log(T+1)))

Theory constants:

* EF21-P:   B*    = 1 + 2 sqrt(1-alpha) / (1 - sqrt(1-alpha))        (Thm 1)
* MARINA-P: Btil* = Lbar0^2 + 2 Lbar0 Ltil0 sqrt((1-p) omega / p)    (Thm 2)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Theory constants
# ---------------------------------------------------------------------------


def ef21p_B_star(alpha: float) -> float:
    """B* = 1 + 2 sqrt(1-alpha)/(1-sqrt(1-alpha)); B* <= 4/alpha - 1."""
    r = (1.0 - alpha) ** 0.5
    if r == 0.0:
        return 1.0
    return 1.0 + 2.0 * r / (1.0 - r)


def marina_p_B_star(L0_bar: float, L0_tilde: float, omega: float, p: float) -> float:
    """Btil* = Lbar0^2 + 2 Lbar0 Ltil0 sqrt((1-p) omega / p)."""
    return L0_bar**2 + 2.0 * L0_bar * L0_tilde * ((1.0 - p) * omega / p) ** 0.5


def ef21p_lambda_star(alpha: float) -> float:
    """lambda* = sqrt(1-alpha)/(1-sqrt(1-alpha)) — Lyapunov weight (Thm 1)."""
    r = (1.0 - alpha) ** 0.5
    if r == 0.0:
        return 1e-12  # V^t degenerates to ||x-x*||^2; weight unused
    return r / (1.0 - r)


def marina_p_lambda_star(L0_bar: float, L0_tilde: float, omega: float, p: float) -> float:
    """lambda* = (Lbar0/Ltil0) sqrt((1-p) omega / p) — Lyapunov weight (Thm 2)."""
    val = (L0_bar / L0_tilde) * ((1.0 - p) * omega / p) ** 0.5
    return max(val, 1e-12)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stepsize:
    """Base: __call__(t, aux) -> gamma. ``aux`` carries Polyak quantities."""

    def __call__(self, t, aux: Optional[dict] = None):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Constant(Stepsize):
    gamma: float = 1e-2

    def __call__(self, t, aux=None):
        return jnp.asarray(self.gamma)


@dataclasses.dataclass(frozen=True)
class Decreasing(Stepsize):
    """gamma_t = gamma0 / sqrt(t+1)   (15)/(25)."""

    gamma0: float = 1e-2

    def __call__(self, t, aux=None):
        return self.gamma0 / jnp.sqrt(t + 1.0)


@dataclasses.dataclass(frozen=True)
class EF21PPolyak(Stepsize):
    """(13): gamma_t = factor * (f(w^t) - f*) / (B* ||df(w^t)||^2).

    aux must provide ``f_w`` (scalar f(w^t)) and ``g_norm_sq``
    (||(1/n) sum_i df_i(w^t)||^2). ``f_star`` defaults to 0 (true for the
    paper's L1 objective).
    """

    alpha: float = 1.0
    f_star: float = 0.0
    factor: float = 1.0

    def __call__(self, t, aux=None):
        B = ef21p_B_star(self.alpha)
        gap = jnp.maximum(aux["f_w"] - self.f_star, 0.0)
        return self.factor * gap / (B * jnp.maximum(aux["g_norm_sq"], 1e-30))


@dataclasses.dataclass(frozen=True)
class MarinaPPolyak(Stepsize):
    """(23): gamma_t = factor * (mean_i f_i(w_i^t) - f*) / denom with

    denom = ||g||^2 + 2 ||g|| sqrt(mean_i ||g_i||^2) sqrt((1-p) omega / p),
    g = (1/n) sum_i df_i(w_i^t).
    aux provides ``f_w`` (= mean_i f_i(w_i^t)), ``g_norm_sq`` and
    ``g_sq_mean`` (= mean_i ||g_i||^2).
    """

    omega: float = 0.0
    p: float = 1.0
    f_star: float = 0.0
    factor: float = 1.0

    def __call__(self, t, aux=None):
        c = ((1.0 - self.p) * self.omega / self.p) ** 0.5
        gnorm = jnp.sqrt(jnp.maximum(aux["g_norm_sq"], 1e-30))
        denom = aux["g_norm_sq"] + 2.0 * gnorm * jnp.sqrt(
            jnp.maximum(aux["g_sq_mean"], 1e-30)
        ) * c
        gap = jnp.maximum(aux["f_w"] - self.f_star, 0.0)
        return self.factor * gap / jnp.maximum(denom, 1e-30)


# ---------------------------------------------------------------------------
# Optimal-constant helpers (used by benchmarks to set theory stepsizes)
# ---------------------------------------------------------------------------


def ef21p_optimal_constant(V0: float, L0: float, alpha: float, T: int) -> float:
    """(11): gamma = sqrt(V0 / (B* L0^2)) / sqrt(T)."""
    B = ef21p_B_star(alpha)
    return (V0 / (B * L0**2)) ** 0.5 / T**0.5


def ef21p_optimal_decreasing_gamma0(V0: float, L0: float, alpha: float, T: int) -> float:
    """(17): gamma0 = sqrt(V0 / (2 B* L0^2 log(T+1)))."""
    import math

    B = ef21p_B_star(alpha)
    return (V0 / (2.0 * B * L0**2 * math.log(T + 1.0))) ** 0.5


def marina_p_optimal_constant(
    V0: float, L0_bar: float, L0_tilde: float, omega: float, p: float, T: int
) -> float:
    """(21): gamma = sqrt(V0 / Btil*) / sqrt(T)."""
    B = marina_p_B_star(L0_bar, L0_tilde, omega, p)
    return (V0 / B) ** 0.5 / T**0.5


def marina_p_optimal_decreasing_gamma0(
    V0: float, L0_bar: float, L0_tilde: float, omega: float, p: float, T: int
) -> float:
    """(27): gamma0 = sqrt(V0 / (2 Btil* log(T+1)))."""
    import math

    B = marina_p_B_star(L0_bar, L0_tilde, omega, p)
    return (V0 / (2.0 * B * math.log(T + 1.0))) ** 0.5


def make_stepsize(spec: str, **kw) -> Stepsize:
    """Registry: ``constant:0.01``, ``decreasing:0.1``, ``polyak_ef21p``,
    ``polyak_marina_p``."""
    parts = spec.split(":")
    kind = parts[0]
    if kind == "constant":
        return Constant(gamma=float(parts[1]) if len(parts) > 1 else kw.get("gamma", 1e-2))
    if kind == "decreasing":
        return Decreasing(gamma0=float(parts[1]) if len(parts) > 1 else kw.get("gamma0", 1e-2))
    if kind == "polyak_ef21p":
        return EF21PPolyak(
            alpha=kw.get("alpha", 1.0),
            f_star=kw.get("f_star", 0.0),
            factor=kw.get("factor", 1.0),
        )
    if kind == "polyak_marina_p":
        return MarinaPPolyak(
            omega=kw.get("omega", 0.0),
            p=kw.get("p", 1.0),
            f_star=kw.get("f_star", 0.0),
            factor=kw.get("factor", 1.0),
        )
    raise ValueError(f"unknown stepsize spec: {spec}")
