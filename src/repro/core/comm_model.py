"""Communication accounting (Definitions 1 & 4 and Appendix A's bit model).

The paper measures server->worker (s2w, downlink) cost in bits per worker:

    bits_per_message(q) = (65 + log2(d)) * q

for a sparse message with q non-zeros (64 value bits + 1 sign bit +
log2(d) index bits). Dense full-precision broadcasts cost 64*d
(no index/sign overhead needed). Natural compression costs 9 bits/value.

These are *wire* costs for the federated WAN link the paper optimizes. The
separate TPU-interconnect cost of our SPMD realization is measured from
compiled HLO in the roofline (launch/roofline.py) — see DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class CommModel:
    d: int
    value_bits: int = 64

    def sparse_bits(self, q: float) -> float:
        """(65 + log2 d) * q  — sparse message with q non-zeros."""
        return (self.value_bits + 1 + math.log2(self.d)) * q

    def dense_bits(self) -> float:
        return float(self.value_bits * self.d)

    def natural_bits(self) -> float:
        return 9.0 * self.d


@dataclasses.dataclass
class CommLedger:
    """Per-worker running totals of s2w and w2s traffic in bits.

    s2w (downlink) is the compressed model broadcast the paper prices;
    w2s (uplink) is the worker->server gradient. Both EF21-P and MARINA-P
    send *exact* uplink gradients (Algorithms 1 & 2), so the uplink cost
    is one dense message per round — tracked here so rounds-to-eps plots
    can report total WAN traffic, not downlink only.
    """

    model: CommModel
    s2w_bits: float = 0.0
    w2s_bits: float = 0.0
    rounds: int = 0

    def log_s2w_sparse(self, q: float):
        self.s2w_bits += self.model.sparse_bits(q)

    def log_s2w_dense(self):
        self.s2w_bits += self.model.dense_bits()

    def log_w2s_sparse(self, q: float):
        self.w2s_bits += self.model.sparse_bits(q)

    def log_w2s_dense(self):
        self.w2s_bits += self.model.dense_bits()

    def tick(self):
        self.rounds += 1


# -- closed-form complexity predictions (Corollaries 1 & 2) -------------------


def ef21p_iteration_complexity(L0: float, R0_sq: float, alpha: float, eps: float) -> float:
    """T = O(L0^2 R0^2 / (alpha eps^2))   (19)."""
    return L0**2 * R0_sq / (alpha * eps**2)


def marina_p_iteration_complexity(
    L0_bar: float, L0_tilde: float, R0_sq: float, omega: float, d: int, zeta: float, eps: float
) -> float:
    """T = O(R0^2/eps^2 (Lbar^2 + Lbar Ltil sqrt(omega (d/zeta - 1))))   (29)."""
    return (
        R0_sq
        / eps**2
        * (L0_bar**2 + L0_bar * L0_tilde * (omega * (d / zeta - 1.0)) ** 0.5)
    )


def per_worker_comm_cost(d: int, zeta: float, T: float) -> float:
    """O(d + zeta T) floats per worker (Corollaries 1 & 2)."""
    return d + zeta * T
