"""Core NN layers: RMSNorm, RoPE, MLPs, GQA/MQA attention, MLA.

Conventions
-----------
* params are plain dict pytrees; every layer provides ``init(cfg, key)``,
  ``apply(params, x, ...)`` and (for attention) a ``decode`` path.
* activations are bf16, softmax statistics and norms fp32.
* projection weights are stored 2-D ``[d_in, d_out]`` with flattened
  head dims so tensor-parallel sharding never depends on head-count
  divisibility (DESIGN.md §5).
* attention over long sequences uses an online-softmax scan over KV chunks
  (XLA-native flash equivalent) so the memory roofline is honest.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .config import MLAConfig, ModelConfig

Array = jax.Array
COMPUTE_DTYPE = jnp.bfloat16


def _dense_init(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else d_in**-0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32)[..., None, :] * freqs  # [...,S,1,hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(cfg_like, key, d_model: int, d_ff: int, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_out": _dense_init(k2, d_ff, d_model)}
    if kind in ("swiglu", "geglu"):
        p["w_in"] = _dense_init(k1, d_model, d_ff)
        p["w_gate"] = _dense_init(k3, d_model, d_ff)
    else:  # relu2 | gelu
        p["w_in"] = _dense_init(k1, d_model, d_ff)
    return p


def mlp_apply(params, x, kind: str):
    h = x @ params["w_in"].astype(x.dtype)
    if kind == "swiglu":
        g = x @ params["w_gate"].astype(x.dtype)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    elif kind == "geglu":
        g = x @ params["w_gate"].astype(x.dtype)
        h = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(x.dtype) * h
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    elif kind == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    else:
        raise ValueError(kind)
    return h @ params["w_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Online-softmax chunked attention (training / prefill)
# ---------------------------------------------------------------------------


def windowed_attention(q: Array, k: Array, v: Array, *, window: int, chunk: int = 512) -> Array:
    """Sliding-window self-attention that only touches in-window KV chunks.

    §Perf optimization (EXPERIMENTS.md): the naive chunked path scans ALL
    S/chunk KV chunks and masks, costing O(S^2) flops even for a 512-token
    window. Here each query chunk attends to exactly the
    ceil((window-1)/chunk)+1 KV chunks that can intersect its window, so
    flops drop to O(S * (window + chunk)). Exact — no approximation.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    hd_v = v.shape[-1]
    G = H // KV
    scale = hd**-0.5
    nq = -(-S // chunk)
    pad = nq * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    w_chunks = -(-(window - 1) // chunk) + 1
    L = w_chunks * chunk
    zpad = (w_chunks - 1) * chunk
    k_ext = jnp.pad(k, ((0, 0), (zpad, 0), (0, 0), (0, 0)))
    v_ext = jnp.pad(v, ((0, 0), (zpad, 0), (0, 0), (0, 0)))
    qg = q.reshape(B, nq, chunk, KV, G, hd)

    def body(_, i):
        qb = jax.lax.dynamic_index_in_dim(qg, i, axis=1, keepdims=False)
        kb = jax.lax.dynamic_slice_in_dim(k_ext, i * chunk, L, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v_ext, i * chunk, L, axis=1)
        q_pos = i * chunk + jnp.arange(chunk)
        k_pos = (i - w_chunks + 1) * chunk + jnp.arange(L)
        s = jnp.einsum("bqkgh,bckh->bqkgc", qb, kb, preferred_element_type=jnp.float32) * scale
        mask = (q_pos[:, None] >= k_pos[None, :]) & (k_pos[None, :] >= 0)
        mask &= q_pos[:, None] - k_pos[None, :] < window
        mask &= k_pos[None, :] < S
        mask &= q_pos[:, None] < S
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - m)
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        out = jnp.einsum("bqkgc,bckh->bqkgh", p, vb.astype(jnp.float32))
        out = out / jnp.maximum(p.sum(-1)[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(body, None, jnp.arange(nq))  # [nq, B, chunk, KV, G, hd_v]
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * chunk, H, hd_v)
    return out[:, :S]


def chunked_attention(
    q: Array,  # [B, Sq, H, hd]
    k: Array,  # [B, Sk, KV, hd]
    v: Array,  # [B, Sk, KV, hd]
    *,
    q_offset: int | Array = 0,
    window: Optional[int] = None,
    chunk: int = 512,
) -> Array:
    """Causal (optionally sliding-window) attention via scan over KV chunks.

    Memory per step is O(B * Sq * chunk) — the XLA-native flash pattern.
    Self-attention with a window shorter than the sequence dispatches to
    :func:`windowed_attention` (in-window chunks only — §Perf).
    """
    if (
        window is not None
        and q.shape[1] == k.shape[1]
        and isinstance(q_offset, int)
        and q_offset == 0
        and window < k.shape[1]
    ):
        return windowed_attention(q, k, v, window=window, chunk=min(chunk, max(window, 128)))
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]  # may differ from hd (MLA)
    G = H // KV
    scale = hd**-0.5
    qg = q.reshape(B, Sq, KV, G, hd)
    nchunks = -(-Sk // chunk)
    pad = nchunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, chunk, KV, hd_v).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        ci, kci, vci = inp
        k_pos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bqkgh,bckh->bqkgc", qg, kci, preferred_element_type=jnp.float32
        ) * scale
        mask = q_pos[:, None] >= k_pos[None, :]  # causal
        mask &= k_pos[None, :] < Sk  # padding
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckh->bqkgh", p, vci.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(nchunks), kc, vc)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd_v).astype(q.dtype)


def decode_attention(
    q: Array,  # [B, 1, H, hd]
    k_cache: Array,  # [B, S, KV, hd]
    v_cache: Array,  # [B, S, KV, hd]
    valid_mask: Array,  # [B, S] bool (or [S])
) -> Array:
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum(
        "bkgh,bskh->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * hd**-0.5
    vm = valid_mask if valid_mask.ndim == 2 else valid_mask[None, :]
    s = jnp.where(vm[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA / MQA attention block
# ---------------------------------------------------------------------------


def attention_init(cfg: ModelConfig, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": _dense_init(k1, cfg.d_model, cfg.q_dim),
        "wk": _dense_init(k2, cfg.d_model, cfg.kv_dim),
        "wv": _dense_init(k3, cfg.d_model, cfg.kv_dim),
        "wo": _dense_init(k4, cfg.q_dim, cfg.d_model, scale=cfg.q_dim**-0.5),
    }


def _qkv(cfg: ModelConfig, params, x, positions):
    B, S, _ = x.shape
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (x @ params["wk"].astype(x.dtype)).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ params["wv"].astype(x.dtype)).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_apply(cfg: ModelConfig, params, x, *, window=None, chunk=512):
    """Training / prefill self-attention. x: [B, S, D]."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(cfg, params, x, positions)
    out = chunked_attention(q, k, v, window=window, chunk=chunk)
    return out.reshape(B, S, cfg.q_dim) @ params["wo"].astype(x.dtype)


def attention_decode(cfg: ModelConfig, params, x, cache, pos, *, window=None):
    """One-token decode. x: [B,1,D]; cache: {k,v: [B, L, KV, hd]} ring buffer
    of length L (= window for local layers, full seq for global)."""
    B = x.shape[0]
    L = cache["k"].shape[1]
    positions = jnp.broadcast_to(pos[None, None] if jnp.ndim(pos) else jnp.full((B, 1), pos), (B, 1))
    q, k, v = _qkv(cfg, params, x, positions)
    slot = pos % L
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    # slot j holds absolute position: j if j <= slot else j - L (previous wrap)
    idx = jnp.arange(L)
    abs_pos = jnp.where(idx <= slot, pos - slot + idx, pos - slot + idx - L)
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if window is not None:
        valid &= pos - abs_pos < window
    out = decode_attention(q, k_cache, v_cache, valid)
    y = out.reshape(B, 1, cfg.q_dim) @ params["wo"].astype(x.dtype)
    return y, {"k": k_cache, "v": v_cache}


def attention_cache_init(cfg: ModelConfig, batch: int, length: int, dtype=COMPUTE_DTYPE):
    shape = (batch, length, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# ---------------------------------------------------------------------------


def mla_init(cfg: ModelConfig, key):
    m: MLAConfig = cfg.mla
    H = cfg.num_heads
    ks = jax.random.split(key, 6)
    qd = H * (m.nope_head_dim + m.rope_head_dim)
    return {
        "wq": _dense_init(ks[0], cfg.d_model, qd),
        "w_dkv": _dense_init(ks[1], cfg.d_model, m.kv_lora_rank),
        "w_krope": _dense_init(ks[2], cfg.d_model, m.rope_head_dim),
        "kv_norm": rmsnorm_init(m.kv_lora_rank),
        "w_uk": _dense_init(ks[3], m.kv_lora_rank, H * m.nope_head_dim),
        "w_uv": _dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim),
        "wo": _dense_init(ks[5], H * m.v_head_dim, cfg.d_model),
    }


def _mla_q(cfg, params, x, positions):
    m = cfg.mla
    H = cfg.num_heads
    B, S, _ = x.shape
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg, params, x, positions):
    m = cfg.mla
    c = rmsnorm(params["kv_norm"], x @ params["w_dkv"].astype(x.dtype), cfg.rms_eps)
    k_rope = (x @ params["w_krope"].astype(x.dtype))[:, :, None, :]  # [B,S,1,rd]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c, k_rope


def mla_apply(cfg: ModelConfig, params, x, *, window=None, chunk=512):
    """Prefill/train MLA: reconstruct per-head K/V from the latent, then run
    standard chunked attention with a concatenated [nope|rope] key."""
    m = cfg.mla
    H = cfg.num_heads
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope = _mla_q(cfg, params, x, positions)
    c, k_rope = _mla_latent(cfg, params, x, positions)
    k_nope = (c @ params["w_uk"].astype(x.dtype)).reshape(B, S, H, m.nope_head_dim)
    v = (c @ params["w_uv"].astype(x.dtype)).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.rope_head_dim))],
        axis=-1,
    )
    out = chunked_attention(q, k, v, window=window, chunk=chunk)
    return out.reshape(B, S, H * m.v_head_dim) @ params["wo"].astype(x.dtype)


def mla_decode(cfg: ModelConfig, params, x, cache, pos, *, window=None):
    """Absorbed-matmul decode (the MLA trick): attention runs directly in the
    kv_lora latent space — the cache stores only [c | k_rope] per token, and
    W_uk / W_uv are absorbed into the query / output projections."""
    m = cfg.mla
    H = cfg.num_heads
    B = x.shape[0]
    L = cache["c"].shape[1]
    positions = jnp.broadcast_to(jnp.reshape(pos, (1, 1)), (B, 1))
    q_nope, q_rope = _mla_q(cfg, params, x, positions)  # [B,1,H,*]
    c, k_rope = _mla_latent(cfg, params, x, positions)  # [B,1,r], [B,1,rd]
    slot = pos % L
    c_cache = jax.lax.dynamic_update_slice(cache["c"], c.astype(cache["c"].dtype), (0, slot, 0))
    kr_cache = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, slot, 0))
    # absorb W_uk into q:  q_eff[h] = q_nope[h] @ W_uk[h]^T  -> latent dim
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.nope_head_dim)
    q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk.astype(x.dtype))  # [B,H,r]
    s = jnp.einsum("bhr,bsr->bhs", q_eff, c_cache, preferred_element_type=jnp.float32)
    s += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], kr_cache, preferred_element_type=jnp.float32)
    s *= (m.nope_head_dim + m.rope_head_dim) ** -0.5
    idx = jnp.arange(L)
    abs_pos = jnp.where(idx <= slot, pos - slot + idx, pos - slot + idx - L)
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if window is not None:
        valid &= pos - abs_pos < window
    s = jnp.where(valid[None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    lat = jnp.einsum("bhs,bsr->bhr", p, c_cache.astype(jnp.float32))  # [B,H,r]
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", lat.astype(x.dtype), w_uv.astype(x.dtype))
    y = out.reshape(B, 1, H * m.v_head_dim) @ params["wo"].astype(x.dtype)
    return y, {"c": c_cache, "k_rope": kr_cache}


def mla_cache_init(cfg: ModelConfig, batch: int, length: int, dtype=COMPUTE_DTYPE):
    m = cfg.mla
    return {
        "c": jnp.zeros((batch, length, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, length, m.rope_head_dim), dtype),
    }
