"""Mixture-of-Experts feed-forward with sort-based capacity dispatch.

Expert-parallel layout: expert weight tensors are ``[E, d_model, d_ff]`` with
``E`` sharded over the ``model`` mesh axis. Dispatch groups tokens by expert
via argsort (no [N, E] one-hot blowup), drops overflow beyond
``capacity = ceil(top_k * N / E * capacity_factor)``, runs a batched
``[E, cap, D] x [E, D, F]`` einsum, and combines with router gates.
Under pjit the dispatch/combine scatter-gathers lower to the all-to-all-style
collective schedule the roofline measures.

Supports deepseek-style shared experts (always-on dense SwiGLU) and llama4
top-1 routing. FLOPs are proportional to *active* experts only.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig
from .layers import _dense_init, mlp_apply, mlp_init


def moe_init(cfg: ModelConfig, key):
    m: MoEConfig = cfg.moe
    ks = jax.random.split(key, 5)
    E, D, F = m.num_experts, cfg.d_model, m.d_ff_expert
    p = {
        "router": _dense_init(ks[0], D, E, scale=D**-0.5),
        "w_in": jax.random.normal(ks[1], (E, D, F), jnp.float32) * D**-0.5,
        "w_gate": jax.random.normal(ks[2], (E, D, F), jnp.float32) * D**-0.5,
        "w_out": jax.random.normal(ks[3], (E, F, D), jnp.float32) * F**-0.5,
    }
    if m.num_shared:
        p["shared"] = mlp_init(cfg, ks[4], D, m.d_ff_shared * m.num_shared, "swiglu")
    return p


def _group_by_expert(expert_ids: jax.Array, num_experts: int, capacity: int):
    """Return (slot, keep) mapping each routed token-copy to an [E*cap] buffer.

    expert_ids: [M] int32. Stable-sorts token-copies by expert, computes each
    copy's position within its expert run, and keeps the first ``capacity``.
    """
    M = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)  # grouped token-copy ids
    sorted_e = expert_ids[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(num_experts), side="left")
    pos = jnp.arange(M) - first[sorted_e]  # rank within expert group
    keep = pos < capacity
    slot = sorted_e * capacity + jnp.minimum(pos, capacity - 1)
    # scatter destination per *original* copy index
    inv = jnp.zeros((M,), jnp.int32).at[order].set(jnp.arange(M, dtype=jnp.int32))
    return slot[inv], keep[inv]


def moe_apply(cfg: ModelConfig, params, x, *, return_aux: bool = False):
    """x: [B, S, D] -> [B, S, D]."""
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    N = B * S
    E, K = m.num_experts, m.top_k
    xf = x.reshape(N, D)
    logits = (xf @ params["router"].astype(x.dtype)).astype(jnp.float32)  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # [N, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    capacity = int(max(1, -(-K * N // E) * m.capacity_factor))
    flat_e = expert_ids.reshape(N * K)
    slot, keep = _group_by_expert(flat_e, E, capacity)
    copy_token = jnp.repeat(jnp.arange(N), K)
    # dispatch ------------------------------------------------------------
    buf = jnp.zeros((E * capacity, D), x.dtype)
    src = jnp.where(keep, slot, E * capacity)  # dropped copies -> OOB (no-op)
    buf = buf.at[src].set(xf[copy_token], mode="drop")
    buf = buf.reshape(E, capacity, D)
    # expert compute --------------------------------------------------------
    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    yb = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(x.dtype))
    # combine ----------------------------------------------------------------
    yb = yb.reshape(E * capacity, D)
    y_copies = yb[jnp.minimum(slot, E * capacity - 1)]
    y_copies = jnp.where(keep[:, None], y_copies, 0.0)
    y_copies = y_copies * gate_vals.reshape(N * K, 1).astype(x.dtype)
    y = jnp.zeros((N, D), x.dtype).at[copy_token].add(y_copies)
    # shared experts --------------------------------------------------------
    if m.num_shared:
        y = y + mlp_apply(params["shared"], xf, "swiglu")
    out = y.reshape(B, S, D)
    if return_aux:
        # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
        me = jnp.mean(probs, axis=0)  # mean router prob per expert
        ce = jnp.zeros((E,)).at[flat_e].add(keep.astype(jnp.float32)) / max(N * K, 1)
        aux = {"load_balance_loss": E * jnp.sum(me * ce),
               "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
        return out, aux
    return out
