"""State-space sequence mixers: Mamba2 (SSD) and RWKV-6 (Finch).

Both use the chunked formulation: within a chunk the recurrence is evaluated
as a masked quadratic (attention-like) form; across chunks a small recurrent
state is carried by ``lax.scan``. This bounds activation memory at
O(S * chunk) instead of O(S^2) or O(S * state) and is the TPU-native way to
run linear-recurrent layers (MXU-friendly chunk matmuls + tiny carry).

Decode is a single recurrence step on an O(1) state — these layers are what
makes ``long_500k`` native for rwkv6/zamba2 (DESIGN.md §4).

Numerical notes:
* Mamba2 decay exponents are always <= 0 within the chunk quadratic — safe.
* RWKV6 per-channel decays are clamped to log w in [-2, -1e-6] and the
  intra-chunk factors are stabilized around the chunk-midpoint cumulative
  decay (documented simplification; chunk=32).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import MambaConfig, ModelConfig, RWKVConfig
from .layers import _dense_init, rmsnorm, rmsnorm_init

Array = jax.Array


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================


def mamba_init(cfg: ModelConfig, key):
    m: MambaConfig = cfg.mamba
    d_inner = m.expand * cfg.d_model
    nheads = d_inner // m.head_dim
    conv_dim = d_inner + 2 * m.state_dim
    ks = jax.random.split(key, 4)
    return {
        # order: [z | x | B | C | dt]
        "in_proj": _dense_init(ks[0], cfg.d_model, 2 * d_inner + 2 * m.state_dim + nheads),
        "conv_w": jax.random.normal(ks[1], (m.conv_width, conv_dim), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((nheads,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "out_norm": rmsnorm_init(d_inner),
        "out_proj": _dense_init(ks[2], d_inner, cfg.d_model),
    }


def _causal_conv(xBC, conv_w, conv_b):
    """Depthwise causal conv along seq. xBC: [B,S,C]; conv_w: [W,C]."""
    W = conv_w.shape[0]
    pads = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pads[:, i : i + xBC.shape[1], :] * conv_w[i].astype(xBC.dtype) for i in range(W)
    )
    return out + conv_b.astype(xBC.dtype)


def _mamba_project(cfg, params, x):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    nheads = d_inner // m.head_dim
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : 2 * d_inner + 2 * m.state_dim]
    dt_raw = zxbcdt[..., 2 * d_inner + 2 * m.state_dim :]
    return z, xBC, dt_raw, d_inner, nheads


def _mamba_post(cfg, params, xin, y, z, dt, Bv=None):
    """y + D skip, gated norm, out proj."""
    m = cfg.mamba
    B_, S, H, hd = y.shape
    xh = xin.reshape(B_, S, H, hd)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B_, S, H * hd)
    y = rmsnorm(params["out_norm"], y, cfg.rms_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return y @ params["out_proj"].astype(y.dtype)


def mamba_apply(cfg: ModelConfig, params, x):
    """Training/prefill SSD. x: [B,S,D] -> [B,S,D]."""
    m = cfg.mamba
    B_, S, _ = x.shape
    z, xBC, dt_raw, d_inner, H = _mamba_project(cfg, params, x)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"]).astype(jnp.float32)).astype(x.dtype)
    xs = xBC[..., :d_inner]
    Bm = xBC[..., d_inner : d_inner + m.state_dim].astype(jnp.float32)  # [B,S,N]
    Cm = xBC[..., d_inner + m.state_dim :].astype(jnp.float32)  # [B,S,N]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    A = -jnp.exp(params["A_log"])  # [H]
    loga = dt * A[None, None, :]  # log decay, <= 0
    xh = xs.reshape(B_, S, H, m.head_dim).astype(jnp.float32)
    xdt = xh * dt[..., None]  # dt-weighted input

    Q = m.chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q

    def r(t):  # chunk reshape
        return t.reshape((B_, nc, Q) + t.shape[2:])

    loga_c, x_c, B_c, C_c = r(loga), r(xdt), r(Bm), r(Cm)
    L = jnp.cumsum(loga_c, axis=2)  # [B,nc,Q,H] inclusive

    # ---- intra-chunk quadratic: scores[t,s] = (C_t.B_s) e^{L_t-L_s} (s<=t)
    CB = jnp.einsum("bctn,bcsn->bcts", C_c, B_c)  # [B,nc,Q,Q]
    dec = jnp.exp(L[:, :, :, None, :] - L[:, :, None, :, :])  # [B,nc,Q,Q,H]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    scores = CB[..., None] * jnp.where(mask[None, None, :, :, None], dec, 0.0)
    y_intra = jnp.einsum("bctsh,bcshd->bcthd", scores, x_c)

    # ---- inter-chunk recurrence over carried state [B,H,N,hd]
    # state_in decays to t as e^{L_t}; token s contributes to the chunk-end
    # state with decay e^{L_last - L_s}.
    w_state = jnp.exp(L[:, :, -1, None, :] - L)  # [B,nc,Q,H] decay from s to chunk end
    state_add = jnp.einsum("bcsh,bcsn,bcshd->bchnd", w_state, B_c, x_c)
    chunk_decay = jnp.exp(L[:, :, -1, :])  # [B,nc,H]

    def body(S_prev, inp):
        add, cdec, Cc, Lc = inp  # [B,H,N,hd], [B,H], [B,Q,N], [B,Q,H]
        y_in = jnp.einsum("bqn,bhnd,bqh->bqhd", Cc, S_prev, jnp.exp(Lc))
        S_new = cdec[:, :, None, None] * S_prev + add
        return S_new, y_in

    S0 = jnp.zeros((B_, H, m.state_dim, m.head_dim), jnp.float32)
    xs_scan = (
        state_add.transpose(1, 0, 2, 3, 4),
        chunk_decay.transpose(1, 0, 2),
        C_c.transpose(1, 0, 2, 3),
        L.transpose(1, 0, 2, 3),
    )
    _, y_inter = jax.lax.scan(body, S0, xs_scan)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)  # [B,nc,Q,H,hd]

    y = (y_intra + y_inter).reshape(B_, S, H, m.head_dim).astype(x.dtype)
    return _mamba_post(cfg, params, xs, y, z, dt)


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    H = d_inner // m.head_dim
    conv_dim = d_inner + 2 * m.state_dim
    return {
        "ssm": jnp.zeros((batch, H, m.state_dim, m.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, m.conv_width - 1, conv_dim), dtype),
    }


def mamba_decode(cfg: ModelConfig, params, x, cache, pos):
    """One-token recurrence. x: [B,1,D]."""
    m = cfg.mamba
    B_ = x.shape[0]
    z, xBC, dt_raw, d_inner, H = _mamba_project(cfg, params, x)
    # conv over cached window
    hist = jnp.concatenate([cache["conv"], xBC.astype(cache["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", hist, params["conv_w"].astype(hist.dtype)) + params[
        "conv_b"
    ].astype(hist.dtype)
    xBC1 = jax.nn.silu(conv_out.astype(jnp.float32))[:, None, :].astype(x.dtype)
    new_conv = hist[:, 1:, :]
    xs = xBC1[..., :d_inner]
    Bm = xBC1[..., d_inner : d_inner + m.state_dim].astype(jnp.float32)[:, 0]
    Cm = xBC1[..., d_inner + m.state_dim :].astype(jnp.float32)[:, 0]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]  # [B,H]
    a = jnp.exp(dt * (-jnp.exp(params["A_log"]))[None, :])  # [B,H]
    xh = xs.reshape(B_, 1, H, m.head_dim).astype(jnp.float32)[:, 0]  # [B,H,hd]
    S_new = a[:, :, None, None] * cache["ssm"] + jnp.einsum(
        "bn,bhd,bh->bhnd", Bm, xh, dt
    )
    y = jnp.einsum("bn,bhnd->bhd", Cm, S_new)[:, None]  # [B,1,H,hd]
    out = _mamba_post(cfg, params, xs, y.astype(x.dtype), z, dt)
    return out, {"ssm": S_new, "conv": new_conv}


# ===========================================================================
# RWKV-6 (Finch)
# ===========================================================================

RWKV_LOGW_MIN, RWKV_LOGW_MAX = -2.0, -1e-6
RWKV_CHUNK = 32


def rwkv_init(cfg: ModelConfig, key):
    r: RWKVConfig = cfg.rwkv
    D = cfg.d_model
    H = D // r.head_dim
    ks = jax.random.split(key, 10)
    p = {
        "mu": jax.random.uniform(ks[0], (5, D), jnp.float32),  # r,k,v,w,g lerps
        "w_r": _dense_init(ks[1], D, D),
        "w_k": _dense_init(ks[2], D, D),
        "w_v": _dense_init(ks[3], D, D),
        "w_g": _dense_init(ks[4], D, D),
        "w0": jnp.full((D,), -0.6, jnp.float32),  # base log-log decay
        "w_lora_a": _dense_init(ks[5], D, r.decay_lora),
        "w_lora_b": jnp.zeros((r.decay_lora, D), jnp.float32),
        "u": jax.random.normal(ks[6], (D,), jnp.float32) * 0.1,  # bonus
        "out_norm": rmsnorm_init(r.head_dim),  # per-head norm
        "w_out": _dense_init(ks[7], D, D),
        # channel mix
        "cm_mu": jax.random.uniform(ks[8], (2, D), jnp.float32),
        "cm_k": _dense_init(ks[9], D, cfg.d_ff),
        "cm_v": _dense_init(jax.random.fold_in(key, 99), cfg.d_ff, D),
        "cm_r": _dense_init(jax.random.fold_in(key, 98), D, D),
    }
    return p


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / provided state at t=0)."""
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _rwkv_proj(cfg, params, x, x_prev):
    r = cfg.rwkv
    D = cfg.d_model
    H = D // r.head_dim
    B_, S, _ = x.shape
    mu = params["mu"].astype(x.dtype)
    mix = lambda i: x + mu[i] * (x_prev - x)
    rv = (mix(0) @ params["w_r"].astype(x.dtype)).reshape(B_, S, H, r.head_dim)
    kv = (mix(1) @ params["w_k"].astype(x.dtype)).reshape(B_, S, H, r.head_dim)
    vv = (mix(2) @ params["w_v"].astype(x.dtype)).reshape(B_, S, H, r.head_dim)
    logw = params["w0"] + jnp.tanh(
        (mix(3) @ params["w_lora_a"].astype(x.dtype)).astype(jnp.float32)
    ) @ params["w_lora_b"]
    logw = -jnp.exp(logw)  # < 0
    logw = jnp.clip(logw, RWKV_LOGW_MIN, RWKV_LOGW_MAX).reshape(B_, S, H, r.head_dim)
    gv = jax.nn.silu((mix(4) @ params["w_g"].astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)
    return rv, kv, vv, logw, gv


def _rwkv_out(cfg, params, wkv, g, x_dtype):
    r = cfg.rwkv
    B_, S, H, hd = wkv.shape
    y = rmsnorm(params["out_norm"], wkv.astype(jnp.float32)).astype(x_dtype)
    y = (y.reshape(B_, S, H * hd) * g.reshape(B_, S, H * hd))
    return y @ params["w_out"].astype(x_dtype)


def rwkv_timemix_apply(cfg: ModelConfig, params, x, x_last=None):
    """Chunked RWKV6 time mix. x: [B,S,D]."""
    r = cfg.rwkv
    B_, S, D = x.shape
    H = D // r.head_dim
    rv, kv, vv, logw, g = _rwkv_proj(cfg, params, x, _shift(x, x_last))
    rv, kv, vv = (t.astype(jnp.float32) for t in (rv, kv, vv))
    u = params["u"].reshape(H, r.head_dim)

    Q = RWKV_CHUNK
    assert S % Q == 0, (S, Q)
    nc = S // Q
    ch = lambda t: t.reshape((B_, nc, Q) + t.shape[2:])
    rc, kc, vc, lw = ch(rv), ch(kv), ch(vv), ch(logw)
    Wc = jnp.cumsum(lw, axis=2)  # [B,nc,Q,H,hd] inclusive cum log decay
    Wprev = Wc - lw  # exclusive (W_{t-1})
    Wref = Wc[:, :, Q // 2 : Q // 2 + 1]  # midpoint stabilizer
    r_t = rc * jnp.exp(Wprev - Wref)
    k_s = kc * jnp.exp(Wref - Wc)
    scores = jnp.einsum("bcthd,bcshd->bchts", r_t, k_s)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)  # strict s < t
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    bonus = jnp.einsum("bcthd,hd,bcthd->bcth", rc, u, kc)  # s == t term
    y_intra = jnp.einsum("bchts,bcshd->bcthd", scores, vc)
    y_intra += bonus[..., None] * vc

    # inter-chunk state S in [B,H,hd_k,hd_v]
    w_end = jnp.exp(Wc[:, :, -1:, :, :] - Wc)  # decay s -> chunk end
    add = jnp.einsum("bcshk,bcshv->bchkv", kc * w_end, vc)
    cdec = jnp.exp(Wc[:, :, -1])  # [B,nc,H,hd]
    r_in = rc * jnp.exp(Wprev)  # decay from chunk start

    def body(S_prev, inp):
        a, cd, rr = inp
        y_in = jnp.einsum("bqhk,bhkv->bqhv", rr, S_prev)
        S_new = cd[:, :, :, None] * S_prev + a
        return S_new, y_in

    S0 = jnp.zeros((B_, H, r.head_dim, r.head_dim), jnp.float32)
    _, y_inter = jax.lax.scan(
        body,
        S0,
        (add.transpose(1, 0, 2, 3, 4), cdec.transpose(1, 0, 2, 3), r_in.transpose(1, 0, 2, 3, 4)),
    )
    y = y_intra + y_inter.transpose(1, 0, 2, 3, 4)
    y = y.reshape(B_, S, H, r.head_dim)
    return _rwkv_out(cfg, params, y, g, x.dtype)


def rwkv_timemix_decode(cfg: ModelConfig, params, x, cache, pos):
    """One-token RWKV6 step. cache: {state:[B,H,k,v], x_last:[B,D]}."""
    r = cfg.rwkv
    B_ = x.shape[0]
    D = cfg.d_model
    H = D // r.head_dim
    rv, kv, vv, logw, g = _rwkv_proj(cfg, params, x, cache["x_last"][:, None, :].astype(x.dtype))
    rv, kv, vv = (t.astype(jnp.float32)[:, 0] for t in (rv, kv, vv))  # [B,H,hd]
    w = jnp.exp(logw.astype(jnp.float32))[:, 0]  # [B,H,hd]
    u = params["u"].reshape(H, r.head_dim)
    S_prev = cache["state"]
    y = jnp.einsum("bhk,bhkv->bhv", rv, S_prev) + jnp.einsum(
        "bhk,hk,bhk,bhv->bhv", rv, u, kv, vv
    )
    S_new = w[..., None] * S_prev + jnp.einsum("bhk,bhv->bhkv", kv, vv)
    out = _rwkv_out(cfg, params, y[:, None], g, x.dtype)
    return out, {"state": S_new, "x_last": x[:, 0].astype(cache["x_last"].dtype)}


def rwkv_chanmix_apply(cfg: ModelConfig, params, x, x_last=None):
    xs = _shift(x, x_last)
    mu = params["cm_mu"].astype(x.dtype)
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    h = jnp.square(jax.nn.relu((xk @ params["cm_k"].astype(x.dtype)).astype(jnp.float32))).astype(x.dtype)
    rgate = jax.nn.sigmoid((xr @ params["cm_r"].astype(x.dtype)).astype(jnp.float32)).astype(x.dtype)
    return rgate * (h @ params["cm_v"].astype(x.dtype))


def rwkv_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    r = cfg.rwkv
    D = cfg.d_model
    H = D // r.head_dim
    return {
        "state": jnp.zeros((batch, H, r.head_dim, r.head_dim), jnp.float32),
        "x_last": jnp.zeros((batch, D), dtype),
        "cm_x_last": jnp.zeros((batch, D), dtype),
    }
