"""Top-level language model: embeddings, stack, heads, loss, decode.

Handles the three input modalities of the assigned pool:
* text          tokens [B, S]
* audio (musicgen)   EnCodec codebook tokens [B, K, S]; K embeddings summed,
                     K output heads (the codec itself is a stub per DESIGN §4)
* vlm (pixtral)      stubbed ViT patch embeddings [B, P, D] prepended to text
                     token embeddings; loss over text positions only
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import transformer as T
from .config import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def lm_init(cfg: ModelConfig, key) -> Dict[str, Any]:
    k_embed, k_stack, k_out = jax.random.split(key, 3)
    params: Dict[str, Any] = {}
    if cfg.num_codebooks:
        params["embed"] = (
            jax.random.normal(k_embed, (cfg.num_codebooks, cfg.vocab_size, cfg.d_model), jnp.float32)
            * 0.02
        )
        if not cfg.tie_embeddings:
            params["unembed"] = (
                jax.random.normal(k_out, (cfg.num_codebooks, cfg.d_model, cfg.vocab_size), jnp.float32)
                * cfg.d_model**-0.5
            )
    else:
        params["embed"] = (
            jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
        )
        if not cfg.tie_embeddings:
            params["unembed"] = (
                jax.random.normal(k_out, (cfg.d_model, cfg.vocab_size), jnp.float32)
                * cfg.d_model**-0.5
            )
    params["stack"] = T.stack_init(cfg, k_stack)
    params["ln_f"] = L.rmsnorm_init(cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# embedding / head helpers
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params, tokens: Array) -> Array:
    emb = params["embed"].astype(L.COMPUTE_DTYPE)
    if cfg.num_codebooks:
        # tokens: [B, K, S] -> sum_k E_k[tok_k]
        parts = [emb[k][tokens[:, k]] for k in range(cfg.num_codebooks)]
        return sum(parts)
    return emb[tokens]


def logits_from_hidden(cfg: ModelConfig, params, h: Array) -> Array:
    if cfg.tie_embeddings:
        if cfg.num_codebooks:
            w = params["embed"].astype(h.dtype)  # [K, V, D]
            return jnp.einsum("bsd,kvd->bksv", h, w)
        return h @ params["embed"].astype(h.dtype).T
    if cfg.num_codebooks:
        w = params["unembed"].astype(h.dtype)  # [K, D, V]
        return jnp.einsum("bsd,kdv->bksv", h, w)
    return h @ params["unembed"].astype(h.dtype)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params,
    batch: Dict[str, Array],
    *,
    window_override: Optional[int] = None,
    chunk: int = 512,
    remat: bool = True,
    act_spec=None,
    remat_policy=None,
) -> Array:
    """Returns logits: [B,S,V] (text/vlm over full seq) or [B,K,S,V].

    ``act_spec``: optional PartitionSpec pinned onto the [B,S,D] hidden
    states after embedding and after every block segment (requires an
    ambient mesh, e.g. ``jax.sharding.use_mesh``). This anchors
    batch-parallel activations so GSPMD never falls back to token
    replication (§Perf, EXPERIMENTS.md).
    """
    constrain = (
        (lambda t: jax.lax.with_sharding_constraint(t, act_spec))
        if act_spec is not None
        else (lambda t: t)
    )
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    if cfg.num_patches:
        patches = batch["patches"].astype(x.dtype)  # [B, P, D]
        x = jnp.concatenate([patches, x], axis=1)
    x = constrain(x)
    x = T.stack_apply(cfg, params["stack"], x, window_override=window_override,
                      chunk=chunk, remat=remat, constrain=constrain,
                      remat_policy=remat_policy)
    x = L.rmsnorm(params["ln_f"], x, cfg.rms_eps)
    if cfg.num_patches:
        x = x[:, cfg.num_patches :]  # logits over text region only
    return logits_from_hidden(cfg, params, x)


def cross_entropy(logits: Array, labels: Array) -> Array:
    """Mean next-token CE. logits [..., S, V] (fp32 statistics), labels [..., S]."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def loss_fn(cfg: ModelConfig, params, batch, **fwd_kw) -> Array:
    logits = forward(cfg, params, batch, **fwd_kw)
    tokens = batch["tokens"]
    if cfg.num_codebooks:
        return cross_entropy(logits[..., :-1, :], tokens[..., 1:])
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


# ---------------------------------------------------------------------------
# decode (serve_step body)
# ---------------------------------------------------------------------------


def cache_init(cfg: ModelConfig, batch: int, cache_len: int, *, window_override=None, dtype=L.COMPUTE_DTYPE):
    return T.stack_cache_init(cfg, batch, cache_len, window_override=window_override, dtype=dtype)


def decode_step(
    cfg: ModelConfig,
    params,
    caches,
    token: Array,  # [B,1] or [B,K,1]
    pos: Array,  # scalar int32
    *,
    window_override: Optional[int] = None,
):
    """One-token decode: returns (logits [B,V] or [B,K,V], new caches)."""
    x = embed_tokens(cfg, params, token)  # [B,1,D]
    x, new_caches = T.stack_decode(cfg, params["stack"], caches, x, pos, window_override=window_override)
    x = L.rmsnorm(params["ln_f"], x, cfg.rms_eps)
    logits = logits_from_hidden(cfg, params, x)
    if cfg.num_codebooks:
        return logits[:, :, 0, :], new_caches  # [B,K,V]
    return logits[:, 0, :], new_caches


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Parameter count via eval_shape (no allocation)."""
    shapes = jax.eval_shape(lambda k: lm_init(cfg, k), jax.random.PRNGKey(0))
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        n = 1
        for s in leaf.shape:
            n *= s
        if active_only and cfg.moe is not None:
            keys = "/".join(str(p) for p in path)
            if any(w in keys for w in ("w_in", "w_gate", "w_out")) and "moe" in keys and "shared" not in keys:
                n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total
