"""Model substrate: configs, layers, SSM/MoE blocks, transformer stack, LM."""
from .config import MambaConfig, MLAConfig, ModelConfig, MoEConfig, RWKVConfig  # noqa: F401
