"""Block composition and layer stacking.

A model is a sequence of blocks, one per entry of ``cfg.block_pattern``:

  attn / attn_local   pre-norm attention (+ window) + MLP
  moe                 pre-norm attention + MoE feed-forward
  mamba               Mamba2 (SSD) block
  rwkv                RWKV6 time-mix + channel-mix block
  shared              weight-tied attention block (zamba2); all ``shared``
                      slots use one parameter set but separate caches.

Stacking plan (compile-time): a periodic pattern scans over stacked
super-block parameters (small HLO => fast 256/512-way SPMD compiles); long
uniform runs are scanned likewise; everything else unrolls.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as MOE
from . import ssm as SSM
from .config import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Stacking plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str                      # "scan" | "unroll"
    block_kinds: Tuple[str, ...]   # super-block pattern (scan) or single kind
    count: int                     # scan repetitions (1 for unroll)
    first_layer: int               # absolute index of first layer in segment


def plan_stack(pattern: Tuple[str, ...]) -> List[Segment]:
    """Cover the pattern with scan segments wherever a period repeats >= 2x.

    Greedy left-to-right: at each position try periods 1..8 and take the one
    covering the most layers as a scanned super-block; otherwise unroll one
    layer. ``shared`` blocks may appear inside scanned super-blocks — their
    (weight-tied) params are closure constants, not scanned.
    """
    n = len(pattern)
    segs: List[Segment] = []
    i = 0
    while i < n:
        best = None  # (covered, p, reps)
        for p in range(1, 9):
            reps = 1
            while i + (reps + 1) * p <= n and pattern[i + reps * p : i + (reps + 1) * p] == pattern[i : i + p]:
                reps += 1
            covered = reps * p
            if reps >= 2 and covered >= 4 and (best is None or covered > best[0]):
                best = (covered, p, reps)
        if best:
            covered, p, reps = best
            segs.append(Segment("scan", tuple(pattern[i : i + p]), reps, i))
            i += covered
        else:
            segs.append(Segment("unroll", (pattern[i],), 1, i))
            i += 1
    return segs


# ---------------------------------------------------------------------------
# Single block init / apply / decode
# ---------------------------------------------------------------------------


def block_init(cfg: ModelConfig, kind: str, key):
    D = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind in ("attn", "attn_local"):
        attn = L.mla_init(cfg, k2) if cfg.mla else L.attention_init(cfg, k2)
        return {
            "ln1": L.rmsnorm_init(D),
            "attn": attn,
            "ln2": L.rmsnorm_init(D),
            "mlp": L.mlp_init(cfg, k3, D, cfg.d_ff, cfg.mlp_kind),
        }
    if kind == "moe":
        attn = L.mla_init(cfg, k2) if cfg.mla else L.attention_init(cfg, k2)
        return {
            "ln1": L.rmsnorm_init(D),
            "attn": attn,
            "ln2": L.rmsnorm_init(D),
            "moe": MOE.moe_init(cfg, k3),
        }
    if kind == "mamba":
        return {"ln": L.rmsnorm_init(D), "mamba": SSM.mamba_init(cfg, k2)}
    if kind == "rwkv":
        return {"ln1": L.rmsnorm_init(D), "ln2": L.rmsnorm_init(D), "rwkv": SSM.rwkv_init(cfg, k2)}
    if kind == "shared":
        return {}  # weight-tied; params live in model["shared_blk"]
    raise ValueError(kind)


def shared_block_init(cfg: ModelConfig, key):
    """zamba2's weight-tied attention block."""
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(cfg, k1),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(cfg, k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind),
    }


def _attn_window(cfg: ModelConfig, kind: str, window_override) -> Optional[int]:
    if window_override is not None and window_override > 0:
        if kind != "attn" or window_override < 0:
            pass
    if kind == "attn_local":
        return cfg.sliding_window
    # window_override: serving-time SWA variant for dense archs (long_500k)
    return window_override


def block_apply(cfg, kind, params, shared_params, x, *, window_override=None, chunk=512):
    """Training / prefill forward for one block. x: [B,S,D]."""
    if kind == "shared":
        params = shared_params
        kind = "attn"
    if kind in ("attn", "attn_local", "moe"):
        w = _attn_window(cfg, kind, window_override)
        h = L.rmsnorm(params["ln1"], x, cfg.rms_eps)
        if cfg.mla:
            h = L.mla_apply(cfg, params["attn"], h, window=w, chunk=chunk)
        else:
            h = L.attention_apply(cfg, params["attn"], h, window=w, chunk=chunk)
        x = x + h
        h = L.rmsnorm(params["ln2"], x, cfg.rms_eps)
        if kind == "moe":
            h = MOE.moe_apply(cfg, params["moe"], h)
        else:
            h = L.mlp_apply(params["mlp"], h, cfg.mlp_kind)
        return x + h
    if kind == "mamba":
        return x + SSM.mamba_apply(cfg, params["mamba"], L.rmsnorm(params["ln"], x, cfg.rms_eps))
    if kind == "rwkv":
        h = L.rmsnorm(params["ln1"], x, cfg.rms_eps)
        x = x + SSM.rwkv_timemix_apply(cfg, params["rwkv"], h)
        h = L.rmsnorm(params["ln2"], x, cfg.rms_eps)
        return x + SSM.rwkv_chanmix_apply(cfg, params["rwkv"], h)
    raise ValueError(kind)


def block_cache_init(cfg, kind, batch, cache_len, *, window_override=None, dtype=L.COMPUTE_DTYPE):
    if kind == "shared":
        kind = "attn"
    if kind in ("attn", "attn_local", "moe"):
        w = _attn_window(cfg, kind, window_override)
        length = min(cache_len, w) if w else cache_len
        if cfg.mla:
            return L.mla_cache_init(cfg, batch, length, dtype)
        return L.attention_cache_init(cfg, batch, length, dtype)
    if kind == "mamba":
        return SSM.mamba_cache_init(cfg, batch, dtype)
    if kind == "rwkv":
        return SSM.rwkv_cache_init(cfg, batch, dtype)
    raise ValueError(kind)


def block_decode(cfg, kind, params, shared_params, x, cache, pos, *, window_override=None):
    if kind == "shared":
        params = shared_params
        kind = "attn"
    if kind in ("attn", "attn_local", "moe"):
        w = _attn_window(cfg, kind, window_override)
        h = L.rmsnorm(params["ln1"], x, cfg.rms_eps)
        if cfg.mla:
            h, cache = L.mla_decode(cfg, params["attn"], h, cache, pos, window=w)
        else:
            h, cache = L.attention_decode(cfg, params["attn"], h, cache, pos, window=w)
        x = x + h
        h = L.rmsnorm(params["ln2"], x, cfg.rms_eps)
        if kind == "moe":
            h = MOE.moe_apply(cfg, params["moe"], h)
        else:
            h = L.mlp_apply(params["mlp"], h, cfg.mlp_kind)
        return x + h, cache
    if kind == "mamba":
        h, new = SSM.mamba_decode(cfg, params["mamba"], L.rmsnorm(params["ln"], x, cfg.rms_eps), cache, pos)
        return x + h, new
    if kind == "rwkv":
        h = L.rmsnorm(params["ln1"], x, cfg.rms_eps)
        tm_cache = {"state": cache["state"], "x_last": cache["x_last"]}
        hh, tm_new = SSM.rwkv_timemix_decode(cfg, params["rwkv"], h, tm_cache, pos)
        x = x + hh
        h2 = L.rmsnorm(params["ln2"], x, cfg.rms_eps)
        cm = SSM.rwkv_chanmix_apply(cfg, params["rwkv"], h2, x_last=cache["cm_x_last"].astype(h2.dtype))
        x = x + cm
        new = {"state": tm_new["state"], "x_last": tm_new["x_last"],
               "cm_x_last": h2[:, 0].astype(cache["cm_x_last"].dtype)}
        return x, new
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stack init / apply / decode
# ---------------------------------------------------------------------------


def stack_init(cfg: ModelConfig, key):
    segs = plan_stack(cfg.block_pattern)
    params: dict = {"segments": []}
    keys = jax.random.split(key, cfg.num_layers + 1)
    if "shared" in cfg.block_pattern:
        params["shared_blk"] = shared_block_init(cfg, keys[-1])
    for seg in segs:
        if seg.kind == "unroll":
            params["segments"].append(block_init(cfg, seg.block_kinds[0], keys[seg.first_layer]))
        else:
            per_rep = []
            p = len(seg.block_kinds)
            for rep in range(seg.count):
                blk = {}
                for j, bk in enumerate(seg.block_kinds):
                    blk[f"b{j}"] = block_init(cfg, bk, keys[seg.first_layer + rep * p + j])
                per_rep.append(blk)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep)
            params["segments"].append(stacked)
    return params


_REMAT_POLICIES = {
    None: None,
    "full": None,  # save nothing, recompute everything
    "dots": "dots_with_no_batch_dims_saveable",
}


def _make_checkpoint(remat, remat_policy):
    if not remat:
        return lambda f: f
    pol_name = _REMAT_POLICIES.get(remat_policy, remat_policy)
    if pol_name is None:
        return jax.checkpoint
    policy = getattr(jax.checkpoint_policies, pol_name)
    return lambda f: jax.checkpoint(f, policy=policy)


def stack_apply(cfg: ModelConfig, params, x, *, window_override=None, chunk=512, remat=True,
                constrain=None, remat_policy=None):
    segs = plan_stack(cfg.block_pattern)
    shared = params.get("shared_blk")
    constrain = constrain or (lambda t: t)
    ckpt = _make_checkpoint(remat, remat_policy)
    for seg, seg_params in zip(segs, params["segments"]):
        if seg.kind == "unroll":
            fn = ckpt(lambda p, h, bk=seg.block_kinds[0]: constrain(block_apply(
                cfg, bk, p, shared, h, window_override=window_override, chunk=chunk
            )))
            x = fn(seg_params, x)
        else:
            def body(h, rep_params, kinds=seg.block_kinds):
                for j, bk in enumerate(kinds):
                    h = constrain(block_apply(
                        cfg, bk, rep_params[f"b{j}"], shared, h,
                        window_override=window_override, chunk=chunk,
                    ))
                return h, None

            x, _ = jax.lax.scan(ckpt(body), x, seg_params)
    return x


def stack_cache_init(cfg: ModelConfig, batch: int, cache_len: int, *, window_override=None, dtype=L.COMPUTE_DTYPE):
    """Per-layer caches, grouped by segment (stacked for scan segments)."""
    segs = plan_stack(cfg.block_pattern)
    caches = []
    for seg in segs:
        if seg.kind == "unroll":
            caches.append(block_cache_init(cfg, seg.block_kinds[0], batch, cache_len,
                                           window_override=window_override, dtype=dtype))
        else:
            one = {
                f"b{j}": block_cache_init(cfg, bk, batch, cache_len,
                                          window_override=window_override, dtype=dtype)
                for j, bk in enumerate(seg.block_kinds)
            }
            caches.append(jax.tree.map(lambda t: jnp.broadcast_to(t, (seg.count,) + t.shape), one))
    return caches


def stack_decode(cfg: ModelConfig, params, caches, x, pos, *, window_override=None):
    segs = plan_stack(cfg.block_pattern)
    shared = params.get("shared_blk")
    new_caches = []
    for seg, seg_params, seg_cache in zip(segs, params["segments"], caches):
        if seg.kind == "unroll":
            x, nc = block_decode(cfg, seg.block_kinds[0], seg_params, shared, x, seg_cache, pos,
                                 window_override=window_override)
            new_caches.append(nc)
        else:
            def body(h, rep, kinds=seg.block_kinds):
                rep_params, rep_cache = rep
                new_rep_cache = {}
                for j, bk in enumerate(kinds):
                    h, new_rep_cache[f"b{j}"] = block_decode(
                        cfg, bk, rep_params[f"b{j}"], shared, h, rep_cache[f"b{j}"], pos,
                        window_override=window_override,
                    )
                return h, new_rep_cache

            x, nc = jax.lax.scan(body, x, (seg_params, seg_cache))
            new_caches.append(nc)
    return x, new_caches
