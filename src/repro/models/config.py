"""Model configuration schema covering all ten assigned architectures.

A :class:`ModelConfig` fully determines parameter shapes, the per-layer block
pattern, and the decode-cache layout. Configs for the assigned architectures
live in ``repro.configs.<id>`` and are registered in ``repro.configs``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0           # shared (always-on) experts, deepseek-style
    d_ff_shared: int = 0          # hidden size of the shared expert(s)
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    every_k: int = 1              # MoE every k-th layer (1 = all marked layers)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => full-rank queries
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    """Mamba2 (SSD) block."""

    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 'Finch' time-mix block."""

    head_dim: int = 64
    decay_lora: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                         # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # block pattern: tuple of block kinds, len == num_layers. Kinds:
    #   attn        full-attention transformer block
    #   attn_local  sliding-window attention block
    #   moe         attention + MoE ffn block
    #   mamba       Mamba2 block
    #   rwkv        RWKV6 block
    #   shared      weight-shared attention block (zamba2)
    block_pattern: Tuple[str, ...] = ()
    mlp_kind: str = "swiglu"            # swiglu | geglu | relu2 | gelu
    rope_theta: float = 10000.0
    use_rope: bool = True
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 4096          # window for attn_local blocks
    long_context_window: int = 8192     # swa window used at long_500k for dense archs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # modality frontends (stubs — DESIGN.md §4):
    num_codebooks: int = 0              # musicgen: EnCodec codebooks (0 = text)
    num_patches: int = 0                # pixtral: ViT patch embeddings per image
    # decode behaviour
    subquadratic: bool = False          # native O(1)/windowed state at 500k?
    notes: str = ""

    def __post_init__(self):
        if self.block_pattern:
            assert len(self.block_pattern) == self.num_layers, self.arch_id

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameters N (from init shapes, no allocation)."""
        from . import lm as _lm

        return _lm.count_params(self)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE counts top_k + shared only)."""
        from . import lm as _lm

        return _lm.count_params(self, active_only=True)


def uniform_pattern(kind: str, n: int) -> Tuple[str, ...]:
    return tuple([kind] * n)


def periodic_pattern(period: Tuple[str, ...], n: int) -> Tuple[str, ...]:
    out = []
    while len(out) < n:
        out.extend(period)
    return tuple(out[:n])
