"""Deterministic synthetic token pipeline with sharded global batches.

Tokens are generated per (step, worker) from folded PRNG keys, so every
worker/process materializes exactly its own shard with no data movement —
the standard trick for synthetic-data scale tests. A Zipf-ish skew makes the
distribution non-uniform (so losses move under training).

``batch_specs`` returns the ShapeDtypeStructs the dry-run lowers against
(the modality-frontend stub of DESIGN.md §4: audio/vlm get precomputed
token/patch embeddings of the right shape).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def _skewed_tokens(key, shape, vocab):
    """Zipf-flavored token draw: u^4 concentrates mass on small ids."""
    u = jax.random.uniform(key, shape)
    return jnp.minimum((u**4 * vocab).astype(jnp.int32), vocab - 1)


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    cfg: ModelConfig
    n_workers: int
    batch_per_worker: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        W, B, S = self.n_workers, self.batch_per_worker, self.seq_len
        cfg = self.cfg
        if cfg.num_codebooks:
            toks = _skewed_tokens(key, (W, B, cfg.num_codebooks, S), cfg.vocab_size)
            return {"tokens": toks}
        if cfg.num_patches:
            S_text = S - cfg.num_patches
            assert S_text > 1, "seq too short for the patch stub"
            k1, k2 = jax.random.split(key)
            return {
                "tokens": _skewed_tokens(k1, (W, B, S_text), cfg.vocab_size),
                "patches": (jax.random.normal(k2, (W, B, cfg.num_patches, cfg.d_model), jnp.bfloat16)),
            }
        return {"tokens": _skewed_tokens(key, (W, B, S), cfg.vocab_size)}


def batch_specs(cfg: ModelConfig, n_workers: int, batch_per_worker: int, seq_len: int):
    """ShapeDtypeStructs for one training batch (dry-run input stand-ins)."""
    W, B, S = n_workers, batch_per_worker, seq_len
    if cfg.num_codebooks:
        return {"tokens": jax.ShapeDtypeStruct((W, B, cfg.num_codebooks, S), jnp.int32)}
    if cfg.num_patches:
        S_text = S - cfg.num_patches
        return {
            "tokens": jax.ShapeDtypeStruct((W, B, S_text), jnp.int32),
            "patches": jax.ShapeDtypeStruct((W, B, cfg.num_patches, cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": jax.ShapeDtypeStruct((W, B, S), jnp.int32)}
