from .pipeline import SyntheticLMData, batch_specs  # noqa: F401
