"""Schema-versioned ``BENCH_<suite>.json`` perf artifacts (DESIGN.md §7.2).

One document per benchmark suite run:

    {
      "schema_version": 1,
      "suite": "wire",
      "created_unix": 1754640000.0,
      "env": {"git_rev": "...", "jax_version": "0.4.37",
              "device_kind": "cpu", "platform": "cpu", "seed": 0},
      "metrics": {
        "wire/sparse_encode": {"us_per_call": 123.4, "value": 0.51,
                               "unit": "GB/s", "count": 1}
      },
      "timers": {"serve/prefill": {"n": 8, "mean_s": ..., "p50_s": ...,
                                   "p99_s": ..., "total_s": ...}},
      "gates": [{"pattern": "wire/*", "field": "value",
                 "direction": "higher", "rtol": 0.9}]
    }

``metrics`` values: ``us_per_call`` comes from benchmark rows, ``value``
is the row's derived number (or the last scalar logged under that name),
``derived`` keeps non-numeric deriveds as strings. Repeated scalar logs
aggregate count + p50/p99. ``gates`` declares which metrics CI regression
checks (benchmarks/bench_diff.py) and with what tolerance — baselines are
self-describing. Units are whatever the field name says: ``us_per_call``
microseconds, ``*_s`` seconds, ``value`` per the ``unit`` field.

The schema is hand-validated (:func:`validate`) — no jsonschema dep.

CLI: ``python -m repro.obs.bench_json BENCH_*.json`` validates files and
exits non-zero on the first invalid one.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Any, Dict, List, Mapping, Optional

from .hist import StreamingHistogram, percentile as _percentile
from .tracker import Tracker

SCHEMA_VERSION = 1
# Exact-percentile retention cap per metric; past it the streaming
# histogram (which has seen every sample, not just the first N) takes
# over — see hist.StreamingHistogram.
_RESERVOIR = 4096


def environment(seed: Optional[int] = None) -> Dict[str, Any]:
    """git rev / jax version / device kind — the provenance block."""
    env: Dict[str, Any] = {"seed": seed}
    try:
        env["git_rev"] = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001 - not a repo / no git
        env["git_rev"] = None
    try:
        import jax

        env["jax_version"] = jax.__version__
        dev = jax.devices()[0]
        env["device_kind"] = dev.device_kind
        env["platform"] = dev.platform
    except Exception:  # noqa: BLE001 - keep artifacts writable without jax
        env.setdefault("jax_version", None)
        env.setdefault("device_kind", None)
        env.setdefault("platform", None)
    return env


class BenchJsonSink(Tracker):
    """Aggregates a run's events into one ``BENCH_<suite>.json`` on finish."""

    def __init__(
        self,
        suite: str,
        out_dir: str,
        *,
        seed: Optional[int] = None,
        gates: Optional[List[Dict[str, Any]]] = None,
    ) -> None:
        self.suite = suite
        self.out_dir = out_dir
        self.seed = seed
        self.gates = list(gates or [])
        self._metrics: Dict[str, Dict[str, Any]] = {}
        self._samples: Dict[str, StreamingHistogram] = {}
        self._timers: Dict[str, StreamingHistogram] = {}
        self.path = os.path.join(out_dir, f"BENCH_{suite}.json")

    # -- event aggregation ---------------------------------------------------

    def _metric_entry(self, name: str) -> Dict[str, Any]:
        return self._metrics.setdefault(name, {"count": 0})

    def _observe(self, name: str, value: Any) -> None:
        entry = self._metric_entry(name)
        entry["count"] += 1
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            entry["derived"] = str(value)
            return
        entry["value"] = float(value)
        self._samples.setdefault(
            name, StreamingHistogram(exact_cap=_RESERVOIR)
        ).add(float(value))

    def _observe_timer(self, name: str, seconds: float) -> None:
        self._timers.setdefault(
            name, StreamingHistogram(exact_cap=_RESERVOIR)
        ).add(float(seconds))

    def emit(self, event: Dict[str, Any]) -> None:
        kind = event.get("kind")
        if kind == "row":
            entry = self._metric_entry(event["name"])
            entry["us_per_call"] = float(event["us_per_call"])
            self._observe(event["name"], event["derived"])
        elif kind == "metrics":
            for k, v in event["metrics"].items():
                self._observe(k, v)
        elif kind == "timer":
            self._observe_timer(event["name"], event["seconds"])
        elif kind == "span":
            # span durations aggregate like timers, namespaced so a span
            # and a timer sharing a name cannot collide
            self._observe_timer(f"span/{event['name']}",
                                float(event["t1"]) - float(event["t0"]))

    # -- document ------------------------------------------------------------

    def document(self) -> Dict[str, Any]:
        metrics: Dict[str, Any] = {}
        for name, entry in self._metrics.items():
            out = dict(entry)
            hist = self._samples.get(name)
            if hist is not None and hist.n > 1:
                out["p50"] = hist.quantile(0.50)
                out["p99"] = hist.quantile(0.99)
            metrics[name] = out
        timers: Dict[str, Any] = {}
        for name, hist in self._timers.items():
            timers[name] = hist.summary("_s")
        return {
            "schema_version": SCHEMA_VERSION,
            "suite": self.suite,
            "created_unix": time.time(),
            "env": environment(seed=self.seed),
            "metrics": metrics,
            "timers": timers,
            "gates": self.gates,
        }

    def finish(self) -> None:
        doc = self.document()
        errors = validate(doc)
        assert not errors, f"BenchJsonSink produced an invalid document: {errors}"
        os.makedirs(self.out_dir, exist_ok=True)
        with open(self.path, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
            fh.write("\n")


# -- schema ------------------------------------------------------------------


def validate(doc: Mapping[str, Any]) -> List[str]:
    """Return a list of schema violations (empty = valid)."""
    errors: List[str] = []

    def expect(cond: bool, msg: str) -> None:
        if not cond:
            errors.append(msg)

    expect(isinstance(doc, Mapping), "document is not an object")
    if not isinstance(doc, Mapping):
        return errors
    expect(doc.get("schema_version") == SCHEMA_VERSION,
           f"schema_version != {SCHEMA_VERSION}: {doc.get('schema_version')!r}")
    expect(isinstance(doc.get("suite"), str) and doc.get("suite"),
           "suite missing or not a string")
    expect(isinstance(doc.get("created_unix"), (int, float)),
           "created_unix missing or not a number")
    env = doc.get("env")
    expect(isinstance(env, Mapping), "env missing or not an object")
    if isinstance(env, Mapping):
        for k in ("git_rev", "jax_version", "device_kind", "platform", "seed"):
            expect(k in env, f"env.{k} missing")
    metrics = doc.get("metrics")
    expect(isinstance(metrics, Mapping), "metrics missing or not an object")
    if isinstance(metrics, Mapping):
        for name, entry in metrics.items():
            if not isinstance(entry, Mapping):
                errors.append(f"metrics[{name!r}] is not an object")
                continue
            expect(isinstance(entry.get("count"), int) and entry["count"] >= 1,
                   f"metrics[{name!r}].count missing or < 1")
            for field in ("us_per_call", "value", "p50", "p99"):
                if field in entry:
                    expect(isinstance(entry[field], (int, float)),
                           f"metrics[{name!r}].{field} is not a number")
    timers = doc.get("timers")
    expect(isinstance(timers, Mapping), "timers missing or not an object")
    if isinstance(timers, Mapping):
        for name, entry in timers.items():
            if not isinstance(entry, Mapping):
                errors.append(f"timers[{name!r}] is not an object")
                continue
            for field in ("n", "total_s", "mean_s", "p50_s", "p99_s"):
                expect(isinstance(entry.get(field), (int, float)),
                       f"timers[{name!r}].{field} missing or not a number")
    gates = doc.get("gates")
    expect(isinstance(gates, list), "gates missing or not a list")
    if isinstance(gates, list):
        for i, g in enumerate(gates):
            if not isinstance(g, Mapping):
                errors.append(f"gates[{i}] is not an object")
                continue
            expect(isinstance(g.get("pattern"), str), f"gates[{i}].pattern missing")
            expect(g.get("field") in ("us_per_call", "value"),
                   f"gates[{i}].field not in (us_per_call, value)")
            expect(g.get("direction") in ("lower", "higher", "eq"),
                   f"gates[{i}].direction not in (lower, higher, eq)")
            expect(isinstance(g.get("rtol"), (int, float)) and g["rtol"] >= 0,
                   f"gates[{i}].rtol missing or negative")
    return errors


def load(path: str) -> Dict[str, Any]:
    with open(path) as fh:
        return json.load(fh)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description="validate BENCH_*.json files")
    ap.add_argument("paths", nargs="+")
    args = ap.parse_args(argv)
    bad = 0
    for path in args.paths:
        errors = validate(load(path))
        if errors:
            bad += 1
            print(f"{path}: INVALID")
            for e in errors:
                print(f"  - {e}")
        else:
            print(f"{path}: ok")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
