"""Span-based round tracing over the Tracker event protocol (DESIGN.md §10).

A *span* is a fourth event kind next to metrics/row/timer:

    span  {"kind": "span", "name": str, "span_id": int, "parent": int|None,
           "t0": float, "t1": float, "attrs": {str: scalar}}

``t0``/``t1`` are ``time.perf_counter()`` seconds (monotonic within one
process — the clock Perfetto export and critical-path analysis need);
``span_id``/``parent`` are a per-tracker deterministic counter, so two
runs that execute the same span sequence produce the same tree ids and
a span stream round-trips through JSONL unchanged.

Spans are opened with the context-manager API on any tracker::

    with tracker.span("round", round=t) as sp:
        with tracker.span("broadcast"):
            ...
        sp.attrs["gamma"] = gamma          # attrs may be added until exit

Nesting is tracked per tracker on the host thread (the training/serving
loops are single-threaded host loops): the innermost open span is the
parent of the next one, across call boundaries — a transport link whose
``send`` runs inside an algorithm's round span parents its ``link/*``
spans under that round automatically. The span *event* is emitted at
exit, so children appear before their parent in the stream; consumers
(analyze.py) reconstruct order-independently.

Instrumented paths emit this vocabulary (see DESIGN.md §10.2):

    round                 one optimizer round / train step / cohort round
      subgrad             the jitted step (subgrad + stepsize + compress, fused)
      stepsize            host read of gamma (attrs carry the reacted value)
      broadcast           downlink delivery section
        encode            wire codec serialization
        link/<name>       one reliable-link send -> ack cycle (LinkStats
                          deltas as attrs: retries, resyncs, delivered)
        link/<name>/retry zero-width marker per retransmission attempt
    serve/request         one DecodeEngine.run call
      prefill, decode     the two serving phases
    serve/delta_sync      one in-flight model-update application
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Dict, List, Optional

SPAN_KIND = "span"


class Span:
    """One open span; mutate ``attrs`` freely until the context exits."""

    __slots__ = ("name", "span_id", "parent", "attrs", "t0", "t1")

    def __init__(self, name: str, span_id: int, parent: Optional[int],
                 attrs: Dict[str, Any]) -> None:
        self.name = name
        self.span_id = span_id
        self.parent = parent
        self.attrs = attrs
        self.t0: float = 0.0
        self.t1: float = 0.0

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def event(self) -> Dict[str, Any]:
        from .tracker import _scalar

        return {
            "kind": SPAN_KIND,
            "name": self.name,
            "span_id": self.span_id,
            "parent": self.parent,
            "t0": self.t0,
            "t1": self.t1,
            "attrs": {str(k): _scalar(v) for k, v in self.attrs.items()},
        }


class _TraceState:
    """Per-tracker open-span stack + deterministic id counter."""

    __slots__ = ("stack", "next_id")

    def __init__(self) -> None:
        self.stack: List[Span] = []
        self.next_id = 0


def _state(tracker) -> _TraceState:
    st = getattr(tracker, "_trace_state", None)
    if st is None:
        st = _TraceState()
        tracker._trace_state = st
    return st


@contextlib.contextmanager
def span(tracker, name: str, **attrs):
    """Open one span on ``tracker``; emits the span event at exit."""
    st = _state(tracker)
    sp = Span(str(name), st.next_id,
              st.stack[-1].span_id if st.stack else None, dict(attrs))
    st.next_id += 1
    st.stack.append(sp)
    sp.t0 = time.perf_counter()
    try:
        yield sp
    finally:
        sp.t1 = time.perf_counter()
        if st.stack and st.stack[-1] is sp:
            st.stack.pop()
        else:  # mis-nested exit: drop back to this span's frame
            while st.stack and st.stack[-1] is not sp:
                st.stack.pop()
            if st.stack:
                st.stack.pop()
        tracker.emit(sp.event())


@contextlib.contextmanager
def maybe_span(tracker, name: str, **attrs):
    """``tracker.span(...)`` when a tracker is attached, else a no-op.

    Yields the open :class:`Span` or ``None`` — call sites guard attr
    writes with ``if sp is not None`` (or write through ``maybe_attr``).
    """
    if tracker is None:
        yield None
    else:
        with span(tracker, name, **attrs) as sp:
            yield sp


def maybe_attr(sp: Optional[Span], **attrs) -> None:
    """Set attrs on a possibly-None span (maybe_span's companion)."""
    if sp is not None:
        sp.attrs.update(attrs)
