"""Post-mortem round-trace analysis CLI (DESIGN.md §10.3).

Reconstructs the span tree from a JSONL event log (repro.obs JsonlTracker
stream carrying ``kind: "span"`` events — trace.py), then:

* validates it (t0 <= t1 on every span, unique ids, no orphan parents);
* exports Chrome/Perfetto ``trace_event`` JSON (``--perfetto out.json``)
  loadable at https://ui.perfetto.dev;
* prints a per-round critical-path table: round duration, phase
  breakdown, the slowest worker link, retry/resync attribution, and
  degraded-round detection (any link span reporting retries, a resync,
  or a failed delivery);
* prints streaming p50/p99 latency histograms per span name
  (:class:`repro.obs.hist.StreamingHistogram` — the same estimator the
  BENCH sink uses).

Usage:
    python -m repro.obs.analyze run.jsonl [--perfetto trace.json]
        [--max-rounds N] [--require-degraded]
    python -m repro.obs.analyze --validate-trace trace.json

Exit code 0 = trace well-formed (and, with ``--require-degraded``, at
least one degraded round attributed to a specific link); 1 otherwise.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

from .hist import StreamingHistogram
from .trace import SPAN_KIND


@dataclasses.dataclass
class SpanNode:
    """One reconstructed span + its children (time-ordered)."""

    name: str
    span_id: int
    parent: Optional[int]
    t0: float
    t1: float
    attrs: Dict[str, Any]
    children: List["SpanNode"] = dataclasses.field(default_factory=list)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def walk(self) -> Iterable["SpanNode"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def signature(self) -> Any:
        """Deterministic structural identity: names, nesting, and attrs
        (timestamps excluded) — what a seeded run must reproduce."""
        return (
            self.name,
            tuple(sorted((k, repr(v)) for k, v in self.attrs.items())),
            tuple(c.signature() for c in self.children),
        )


def span_events(events: Iterable[Mapping[str, Any]]) -> List[Dict[str, Any]]:
    return [dict(e) for e in events if e.get("kind") == SPAN_KIND]


def validate_spans(events: Iterable[Mapping[str, Any]]) -> List[str]:
    """Schema violations in a span event stream (empty = valid)."""
    errors: List[str] = []
    seen: Dict[int, str] = {}
    spans = span_events(events)
    for i, e in enumerate(spans):
        where = f"span[{i}] ({e.get('name')!r})"
        for field in ("name", "span_id", "t0", "t1"):
            if field not in e:
                errors.append(f"{where}: missing {field}")
        if not isinstance(e.get("attrs", {}), Mapping):
            errors.append(f"{where}: attrs not an object")
        sid = e.get("span_id")
        if isinstance(sid, int):
            if sid in seen:
                errors.append(f"{where}: duplicate span_id {sid} (also {seen[sid]!r})")
            seen[sid] = e.get("name")
        t0, t1 = e.get("t0"), e.get("t1")
        if isinstance(t0, (int, float)) and isinstance(t1, (int, float)) and t1 < t0:
            errors.append(f"{where}: t1 < t0 ({t1} < {t0})")
    for i, e in enumerate(spans):
        parent = e.get("parent")
        if parent is not None and parent not in seen:
            errors.append(
                f"span[{i}] ({e.get('name')!r}): orphan parent id {parent}"
            )
    return errors


def build_tree(events: Iterable[Mapping[str, Any]]) -> List[SpanNode]:
    """Span events (any order) -> time-ordered forest of root SpanNodes."""
    nodes: Dict[int, SpanNode] = {}
    for e in span_events(events):
        nodes[e["span_id"]] = SpanNode(
            name=e["name"], span_id=e["span_id"], parent=e.get("parent"),
            t0=float(e["t0"]), t1=float(e["t1"]), attrs=dict(e.get("attrs", {})),
        )
    roots: List[SpanNode] = []
    for n in nodes.values():
        if n.parent is not None and n.parent in nodes:
            nodes[n.parent].children.append(n)
        else:
            roots.append(n)
    for n in nodes.values():
        n.children.sort(key=lambda c: (c.t0, c.span_id))
    roots.sort(key=lambda r: (r.t0, r.span_id))
    return roots


# -- Perfetto export ----------------------------------------------------------


def to_perfetto(events: Iterable[Mapping[str, Any]],
                *, process_name: str = "repro") -> Dict[str, Any]:
    """Chrome ``trace_event`` JSON (complete "X" events, µs timestamps).

    Spans from one host thread strictly nest, so everything lands on one
    track; ``span_id``/``parent`` travel in ``args`` alongside the attrs
    so Perfetto's query layer can rebuild the tree.
    """
    trace_events: List[Dict[str, Any]] = [
        {"ph": "M", "pid": 1, "name": "process_name",
         "args": {"name": process_name}},
        {"ph": "M", "pid": 1, "tid": 1, "name": "thread_name",
         "args": {"name": "host-loop"}},
    ]
    for e in span_events(events):
        args = {k: v for k, v in e.get("attrs", {}).items()}
        args["span_id"] = e["span_id"]
        if e.get("parent") is not None:
            args["parent"] = e["parent"]
        trace_events.append(
            {
                "name": e["name"],
                "cat": "span",
                "ph": "X",
                "ts": float(e["t0"]) * 1e6,
                "dur": max(0.0, (float(e["t1"]) - float(e["t0"])) * 1e6),
                "pid": 1,
                "tid": 1,
                "args": args,
            }
        )
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def validate_perfetto(doc: Mapping[str, Any]) -> List[str]:
    """Well-formedness of an exported Chrome trace document."""
    errors: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    for i, e in enumerate(evs):
        if not isinstance(e, Mapping):
            errors.append(f"traceEvents[{i}] is not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "M"):
            errors.append(f"traceEvents[{i}].ph {ph!r} not in (X, M)")
            continue
        if not isinstance(e.get("name"), str):
            errors.append(f"traceEvents[{i}].name missing")
        if ph == "X":
            for field in ("ts", "dur", "pid", "tid"):
                if not isinstance(e.get(field), (int, float)):
                    errors.append(f"traceEvents[{i}].{field} missing or non-numeric")
            if isinstance(e.get("dur"), (int, float)) and e["dur"] < 0:
                errors.append(f"traceEvents[{i}].dur negative")
    return errors


# -- per-round critical path --------------------------------------------------


@dataclasses.dataclass
class RoundReport:
    """Critical-path attribution for one round span."""

    index: int
    label: str
    duration_s: float
    phases: Dict[str, float]            # direct-child name -> seconds
    slowest_link: Optional[str]
    slowest_link_s: float
    retries: int
    resyncs: int
    failed_links: List[str]
    degraded: bool
    culprit: Optional[str]              # link name the degradation traces to
    gamma: Optional[float]

    def row(self) -> str:
        phases = " ".join(f"{k}={v * 1e3:.2f}ms" for k, v in self.phases.items())
        tail = ""
        if self.degraded:
            tail = f"  DEGRADED <- {self.culprit} (retries={self.retries}, resyncs={self.resyncs})"
        link = (f"{self.slowest_link}={self.slowest_link_s * 1e3:.2f}ms"
                if self.slowest_link else "-")
        gamma = f"{self.gamma:.3e}" if self.gamma is not None else "-"
        return (f"{self.index:>5}  {self.duration_s * 1e3:>9.2f}ms  gamma={gamma:<10} "
                f"slowest_link={link:<24} {phases}{tail}")


def _link_spans(node: SpanNode) -> List[SpanNode]:
    return [s for s in node.walk()
            if s.name.startswith("link/") and not s.name.endswith("/retry")]


def round_reports(roots: List[SpanNode]) -> List[RoundReport]:
    """One report per ``round`` / ``serve/request`` root span."""
    out: List[RoundReport] = []
    idx = 0
    for r in roots:
        if r.name not in ("round", "serve/request"):
            continue
        links = _link_spans(r)
        retries = sum(int(s.attrs.get("retries", 0) or 0) for s in links)
        resyncs = sum(int(s.attrs.get("resyncs", 0) or 0) for s in links)
        failed = [s.name for s in links if s.attrs.get("delivered") is False]
        # degradation attribution: the link with failed delivery, else the
        # one that spent the most repair effort (retries + resyncs)
        culprit = None
        if failed:
            culprit = failed[0]
        else:
            worst = max(links, default=None,
                        key=lambda s: (int(s.attrs.get("retries", 0) or 0)
                                       + int(s.attrs.get("resyncs", 0) or 0)))
            if worst is not None and (int(worst.attrs.get("retries", 0) or 0)
                                      + int(worst.attrs.get("resyncs", 0) or 0)) > 0:
                culprit = worst.name
        slowest = max(links, default=None, key=lambda s: s.duration)
        gamma = None
        for s in r.walk():
            if "gamma" in s.attrs:
                gamma = float(s.attrs["gamma"])
                break
        label = str(r.attrs.get("round", r.attrs.get("step", idx)))
        out.append(
            RoundReport(
                index=idx,
                label=label,
                duration_s=r.duration,
                phases={c.name: c.duration for c in r.children},
                slowest_link=slowest.name if slowest is not None else None,
                slowest_link_s=slowest.duration if slowest is not None else 0.0,
                retries=retries,
                resyncs=resyncs,
                failed_links=failed,
                degraded=culprit is not None,
                culprit=culprit,
                gamma=gamma,
            )
        )
        idx += 1
    return out


def latency_histograms(events: Iterable[Mapping[str, Any]]) -> Dict[str, StreamingHistogram]:
    """Per-span-name streaming duration histograms (seconds)."""
    hists: Dict[str, StreamingHistogram] = {}
    for e in span_events(events):
        h = hists.setdefault(e["name"], StreamingHistogram())
        h.add(float(e["t1"]) - float(e["t0"]))
    return hists


# -- CLI ----------------------------------------------------------------------


def _read_jsonl(path: str) -> List[Dict[str, Any]]:
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def report(events: List[Dict[str, Any]], *, max_rounds: Optional[int] = None) -> Tuple[str, int]:
    """(rendered report, number of degraded rounds)."""
    roots = build_tree(events)
    reports = round_reports(roots)
    lines = [f"spans: {len(span_events(events))}   round-level spans: {len(reports)}"]
    degraded = [r for r in reports if r.degraded]
    shown = reports if max_rounds is None else reports[:max_rounds]
    if shown:
        lines.append("\nround  duration     per-round critical path")
        for r in shown:
            lines.append(r.row())
        if len(shown) < len(reports):
            lines.append(f"... ({len(reports) - len(shown)} more rounds)")
    lines.append(
        f"\ndegraded rounds: {len(degraded)}/{len(reports)}"
        + (
            "  (culprits: "
            + ", ".join(sorted({r.culprit for r in degraded if r.culprit}))
            + ")"
            if degraded
            else ""
        )
    )
    hists = latency_histograms(events)
    if hists:
        lines.append("\nspan latency (streaming histogram):")
        lines.append(f"{'name':<28} {'n':>6} {'p50':>12} {'p99':>12}")
        for name in sorted(hists):
            h = hists[name]
            lines.append(
                f"{name:<28} {h.n:>6} {h.quantile(0.5) * 1e3:>10.3f}ms "
                f"{h.quantile(0.99) * 1e3:>10.3f}ms"
            )
    return "\n".join(lines), len(degraded)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("log", nargs="?", help="JSONL event log with span events")
    ap.add_argument("--perfetto", metavar="OUT",
                    help="write Chrome/Perfetto trace_event JSON here")
    ap.add_argument("--max-rounds", type=int, default=24,
                    help="rows shown in the per-round table (default 24)")
    ap.add_argument("--require-degraded", action="store_true",
                    help="exit non-zero unless >=1 degraded round is attributed")
    ap.add_argument("--validate-trace", metavar="TRACE_JSON",
                    help="validate a previously exported Chrome trace and exit")
    args = ap.parse_args(argv)

    if args.validate_trace:
        with open(args.validate_trace) as fh:
            doc = json.load(fh)
        errors = validate_perfetto(doc)
        if errors:
            print(f"{args.validate_trace}: INVALID")
            for e in errors:
                print(f"  - {e}")
            return 1
        n = sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
        print(f"{args.validate_trace}: ok ({n} span events)")
        return 0

    if not args.log:
        ap.error("provide a JSONL log (or --validate-trace)")
    events = _read_jsonl(args.log)
    errors = validate_spans(events)
    if errors:
        print(f"{args.log}: INVALID span stream")
        for e in errors:
            print(f"  - {e}")
        return 1

    if args.perfetto:
        doc = to_perfetto(events)
        pf_errors = validate_perfetto(doc)
        assert not pf_errors, f"exporter produced an invalid trace: {pf_errors}"
        with open(args.perfetto, "w") as fh:
            json.dump(doc, fh)
        print(f"wrote {args.perfetto} "
              f"({sum(1 for e in doc['traceEvents'] if e['ph'] == 'X')} events; "
              "load at https://ui.perfetto.dev)")

    text, n_degraded = report(events, max_rounds=args.max_rounds)
    print(text)
    if args.require_degraded and n_degraded == 0:
        print("FAIL: no degraded round attributed (--require-degraded)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
