"""Shared process-wide logger + default event tracker (DESIGN.md §7.3).

``get_logger(name)`` gives every CLI (dryrun, reanalyze, benchmarks) one
consistently formatted human stream instead of ad-hoc ``print``s.

``default_tracker()`` is the structured twin: a process-wide tracker that
mirrors events into the JSONL file named by ``REPRO_OBS_JSONL`` (if set),
so dry-run compile timings land in the same event stream as benchmark
events. Without the env var it is a no-op sink — callers never guard.
"""
from __future__ import annotations

import logging
import os
import sys
from typing import Optional

from .tracker import CompositeTracker, JsonlTracker, NullTracker, Tracker

_FORMAT = "[%(name)s] %(message)s"
_configured = False
_default_tracker: Optional[Tracker] = None


def get_logger(name: str = "repro") -> logging.Logger:
    """Stdout logger with the repo's one-line format, configured once."""
    global _configured
    root = logging.getLogger("repro")
    if not _configured:
        handler = logging.StreamHandler(sys.stdout)
        handler.setFormatter(logging.Formatter(_FORMAT))
        root.addHandler(handler)
        root.setLevel(os.environ.get("REPRO_OBS_LOGLEVEL", "INFO").upper())
        root.propagate = False
        _configured = True
    return root if name in ("repro", None) else logging.getLogger(f"repro.{name}")


def default_tracker() -> Tracker:
    """Process-wide structured sink; JSONL-backed iff REPRO_OBS_JSONL is set."""
    global _default_tracker
    if _default_tracker is None:
        path = os.environ.get("REPRO_OBS_JSONL")
        _default_tracker = (
            CompositeTracker(JsonlTracker(path)) if path else NullTracker()
        )
    return _default_tracker


def reset_default_tracker() -> None:
    """Drop the cached default (tests re-point REPRO_OBS_JSONL)."""
    global _default_tracker
    if _default_tracker is not None:
        _default_tracker.finish()
    _default_tracker = None
