"""repro.obs — unified tracking, telemetry, and persistent perf artifacts.

Three layers (DESIGN.md §7):

* :mod:`~repro.obs.tracker` — the Tracker protocol and composable backends
  (in-memory, JSONL event log, stdout CSV, composite fan-out);
* :mod:`~repro.obs.bench_json` — the schema-versioned ``BENCH_<suite>.json``
  sink, validator and provenance capture (git rev, jax version, device);
* :mod:`~repro.obs.loggers` — the shared human logger + process-default
  structured sink used by the launch CLIs;
* :mod:`~repro.obs.trace` — span-based round tracing (``tracker.span``)
  over the same event protocol (DESIGN.md §10);
* :mod:`~repro.obs.analyze` — the post-mortem CLI: span-tree validation,
  Chrome/Perfetto export, per-round critical-path attribution;
* :mod:`~repro.obs.hist` — streaming percentile histograms shared by the
  BENCH sink and the analyzer.

Regression gating against committed baselines lives in
``benchmarks/bench_diff.py`` (it consumes the ``gates`` block these
artifacts carry).
"""
from .bench_json import SCHEMA_VERSION, BenchJsonSink, environment, load, validate
from .hist import StreamingHistogram, percentile
from .loggers import default_tracker, get_logger, reset_default_tracker
from .trace import SPAN_KIND, Span, maybe_attr, maybe_span, span
from .tracker import (
    CompositeTracker,
    CsvStdoutTracker,
    JsonlTracker,
    MemoryTracker,
    NullTracker,
    Tracker,
    events_equal,
    flatten_metrics,
    read_jsonl,
)

__all__ = [
    "SCHEMA_VERSION",
    "SPAN_KIND",
    "BenchJsonSink",
    "CompositeTracker",
    "CsvStdoutTracker",
    "JsonlTracker",
    "MemoryTracker",
    "NullTracker",
    "Span",
    "StreamingHistogram",
    "Tracker",
    "default_tracker",
    "environment",
    "events_equal",
    "flatten_metrics",
    "get_logger",
    "load",
    "maybe_attr",
    "maybe_span",
    "percentile",
    "read_jsonl",
    "reset_default_tracker",
    "span",
    "validate",
]
