"""repro.obs — unified tracking, telemetry, and persistent perf artifacts.

Three layers (DESIGN.md §7):

* :mod:`~repro.obs.tracker` — the Tracker protocol and composable backends
  (in-memory, JSONL event log, stdout CSV, composite fan-out);
* :mod:`~repro.obs.bench_json` — the schema-versioned ``BENCH_<suite>.json``
  sink, validator and provenance capture (git rev, jax version, device);
* :mod:`~repro.obs.loggers` — the shared human logger + process-default
  structured sink used by the launch CLIs.

Regression gating against committed baselines lives in
``benchmarks/bench_diff.py`` (it consumes the ``gates`` block these
artifacts carry).
"""
from .bench_json import SCHEMA_VERSION, BenchJsonSink, environment, load, validate
from .loggers import default_tracker, get_logger, reset_default_tracker
from .tracker import (
    CompositeTracker,
    CsvStdoutTracker,
    JsonlTracker,
    MemoryTracker,
    NullTracker,
    Tracker,
    events_equal,
    flatten_metrics,
    read_jsonl,
)

__all__ = [
    "SCHEMA_VERSION",
    "BenchJsonSink",
    "CompositeTracker",
    "CsvStdoutTracker",
    "JsonlTracker",
    "MemoryTracker",
    "NullTracker",
    "Tracker",
    "default_tracker",
    "environment",
    "events_equal",
    "flatten_metrics",
    "get_logger",
    "load",
    "read_jsonl",
    "reset_default_tracker",
    "validate",
]
