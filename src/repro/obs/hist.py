"""Streaming percentile estimation (DESIGN.md §7.2 / §10.4).

Two pieces, shared by the BENCH sink (bench_json.py) and the trace
analyzer (analyze.py):

* :func:`percentile` — linear-interpolation percentile over a sorted
  list (the R-7 / numpy default). Replaces the old nearest-rank
  rounding, which is biased for small n (p50 of [0, 1] must be 0.5,
  not one of the endpoints).
* :class:`StreamingHistogram` — a fixed-bin, sign-aware log-spaced
  histogram with O(1) memory per distinct bin and O(1) updates. Up to
  ``exact_cap`` samples the quantiles are exact (linear interpolation
  over the retained sample list); past the cap the estimate comes from
  the histogram, which has seen *every* sample — unlike the old
  first-N-capped reservoir, whose p99 was biased toward warm-up because
  only the first 4096 observations were ever retained.

Bin layout: |v| is bucketed geometrically with ``bins_per_decade`` bins
per power of ten between 1e-12 and 1e12 (clamped outside), mirrored for
negative values, with one dedicated zero bin. At the default 64 bins
per decade the worst-case relative quantile error past the exact cap is
10^(1/64) - 1 ≈ 3.7%.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

_LO_EXP = -12   # |v| <= 10^LO_EXP lands in the innermost bin
_HI_EXP = 12    # |v| >= 10^HI_EXP lands in the outermost bin


def percentile(sorted_vals: List[float], q: float) -> float:
    """Linear-interpolation percentile of an ascending-sorted list."""
    n = len(sorted_vals)
    if n == 0:
        return float("nan")
    pos = q * (n - 1)
    lo = max(0, min(n - 1, int(math.floor(pos))))
    hi = min(n - 1, lo + 1)
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


class StreamingHistogram:
    """Fixed-bin streaming histogram over arbitrary reals.

    ``add`` is O(1); memory is bounded by ``exact_cap`` retained samples
    plus one counter per non-empty bin (itself bounded by the fixed bin
    grid). ``quantile`` is exact while n <= exact_cap and a <=~4%
    relative-error estimate afterwards — computed over *all* samples,
    not a warm-up prefix.
    """

    def __init__(self, *, bins_per_decade: int = 64, exact_cap: int = 4096) -> None:
        assert bins_per_decade > 0 and exact_cap >= 0
        self.bins_per_decade = bins_per_decade
        self.exact_cap = exact_cap
        self._counts: Dict[int, int] = {}
        self._exact: Optional[List[float]] = [] if exact_cap > 0 else None
        self._sorted = True
        self.n = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- bin mapping ----------------------------------------------------------

    def _bin_of(self, v: float) -> int:
        """Signed bin index: 0 for (near-)zero, ±(1 + offset) otherwise."""
        if v == 0.0 or not math.isfinite(v):
            return 0
        e = math.log10(abs(v))
        e = min(max(e, _LO_EXP), _HI_EXP - 1e-9)
        idx = 1 + int(math.floor((e - _LO_EXP) * self.bins_per_decade))
        return idx if v > 0 else -idx

    def _bin_edges(self, b: int) -> tuple:
        """(lo, hi) value edges of signed bin b, lo <= hi."""
        if b == 0:
            eps = 10.0 ** _LO_EXP
            return (-eps, eps)
        k = abs(b) - 1
        lo = 10.0 ** (_LO_EXP + k / self.bins_per_decade)
        hi = 10.0 ** (_LO_EXP + (k + 1) / self.bins_per_decade)
        return (lo, hi) if b > 0 else (-hi, -lo)

    # -- updates --------------------------------------------------------------

    def add(self, v: float) -> None:
        v = float(v)
        if math.isnan(v):
            return
        self.n += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        b = self._bin_of(v)
        self._counts[b] = self._counts.get(b, 0) + 1
        if self._exact is not None:
            if len(self._exact) < self.exact_cap:
                self._exact.append(v)
                self._sorted = False
            else:  # past the cap the histogram takes over
                self._exact = None

    def extend(self, vals) -> None:
        for v in vals:
            self.add(v)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else float("nan")

    # -- quantiles ------------------------------------------------------------

    def quantile(self, q: float) -> float:
        if self.n == 0:
            return float("nan")
        if self._exact is not None:
            if not self._sorted:
                self._exact.sort()
                self._sorted = True
            return percentile(self._exact, q)
        # histogram estimate: find the bin holding the target rank, then
        # interpolate linearly across the bin's value edges
        target = q * (self.n - 1)
        seen = 0
        for b in sorted(self._counts):
            c = self._counts[b]
            if seen + c > target:
                lo, hi = self._bin_edges(b)
                frac = (target - seen) / c
                est = lo + (hi - lo) * frac
                return min(max(est, self.min), self.max)
            seen += c
        return self.max

    def summary(self, suffix: str = "") -> Dict[str, float]:
        """n / total / mean / p50 / p99 block (key suffix e.g. ``_s``)."""
        return {
            "n": self.n,
            f"total{suffix}": self.total,
            f"mean{suffix}": self.mean,
            f"p50{suffix}": self.quantile(0.50),
            f"p99{suffix}": self.quantile(0.99),
        }
