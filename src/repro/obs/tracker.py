"""Tracker protocol + composite backends (DESIGN.md §7).

A tracker receives *events* — normalized dicts with a ``kind``:

    metrics  {"kind": "metrics", "step": int|None, "wall_time": float,
              "metrics": {flat_name: scalar|str}}
    row      {"kind": "row", "name": str, "us_per_call": float,
              "derived": scalar|str, "wall_time": float}
    timer    {"kind": "timer", "name": str, "seconds": float,
              "step": int|None, "wall_time": float}
    span     {"kind": "span", "name": str, "span_id": int, "parent": int|None,
              "t0": float, "t1": float, "attrs": {...}}      (trace.py, §10)
    profile  {"kind": "profile", "name": str, "trace_dir": str,
              "wall_time": float}                (jax.profiler provenance)

``log`` flattens nested dicts with "/" and coerces jax/numpy scalars to
python floats, so every backend sees the same flat schema. ``row`` is the
benchmark-harness shape (today's ``name,us_per_call,derived`` CSV line).
``time_block`` is a ``block_until_ready``-correct host timer: the handle's
``block(x)`` forces async dispatch before the clock stops, so jitted work
is charged to the block that launched it.

Backends compose: :class:`CompositeTracker` fans every event out, so one
call site can feed the stdout CSV, a JSONL event log, and the
``BENCH_*.json`` aggregator (bench_json.py) at once.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Any, Dict, IO, Iterable, List, Mapping, Optional


def _scalar(v: Any) -> Any:
    """Coerce 0-d jax/numpy values to python scalars; pass strings/bools through."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    for attr in ("item",):  # numpy scalars, 0-d arrays, jax arrays
        fn = getattr(v, attr, None)
        if callable(fn):
            try:
                return fn()
            except (TypeError, ValueError):
                break
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


def flatten_metrics(d: Mapping[str, Any], *, sep: str = "/", prefix: str = "") -> Dict[str, Any]:
    """Flatten nested dicts: {"a": {"b": 1}} -> {"a/b": 1}; scalars coerced."""
    out: Dict[str, Any] = {}
    for k, v in d.items():
        name = f"{prefix}{sep}{k}" if prefix else str(k)
        if isinstance(v, Mapping):
            out.update(flatten_metrics(v, sep=sep, prefix=name))
        else:
            out[name] = _scalar(v)
    return out


class _TimerHandle:
    """Yielded by ``time_block``; ``block(x)`` forces completion of jitted work."""

    def __init__(self) -> None:
        self.seconds: Optional[float] = None

    def block(self, x: Any) -> Any:
        import jax

        return jax.block_until_ready(x)


class Tracker:
    """Base tracker: backends override :meth:`emit` (and maybe :meth:`finish`)."""

    def emit(self, event: Dict[str, Any]) -> None:
        raise NotImplementedError

    def finish(self) -> None:
        """Flush/close. Composite calls this once per run."""

    # -- logging API ---------------------------------------------------------

    def log(self, metrics: Mapping[str, Any], *, step: Optional[int] = None) -> None:
        self.emit(
            {
                "kind": "metrics",
                "step": None if step is None else int(step),
                "wall_time": time.time(),
                "metrics": flatten_metrics(metrics),
            }
        )

    def log_row(self, name: str, us_per_call: float, derived: Any) -> None:
        """One benchmark row — today's ``name,us_per_call,derived`` CSV line."""
        self.emit(
            {
                "kind": "row",
                "name": str(name),
                "us_per_call": float(us_per_call),
                "derived": _scalar(derived),
                "wall_time": time.time(),
            }
        )

    @contextlib.contextmanager
    def time_block(self, name: str, *, step: Optional[int] = None):
        """Host timer; call ``handle.block(out)`` on jax outputs inside the block."""
        handle = _TimerHandle()
        t0 = time.perf_counter()
        try:
            yield handle
        finally:
            handle.seconds = time.perf_counter() - t0
            self.emit(
                {
                    "kind": "timer",
                    "name": str(name),
                    "seconds": handle.seconds,
                    "step": None if step is None else int(step),
                    "wall_time": time.time(),
                }
            )

    def span(self, name: str, **attrs):
        """Open a trace span (DESIGN.md §10) — ``with tracker.span("round",
        round=t) as sp:``. Nested spans parent automatically; the span
        event is emitted through :meth:`emit` at exit."""
        from .trace import span as _span

        return _span(self, name, **attrs)

    @contextlib.contextmanager
    def profile(self, name: str, trace_dir: Optional[str] = None):
        """jax.profiler trace around a block; no-op unless a trace dir is
        given (or REPRO_OBS_TRACE_DIR is set). When a trace is written, a
        ``{"kind": "profile", "name", "trace_dir"}`` event records its
        location, so profiler artifacts are discoverable from the event
        log instead of silently landing on disk."""
        trace_dir = trace_dir or os.environ.get("REPRO_OBS_TRACE_DIR")
        if not trace_dir:
            yield
            return
        import jax

        path = os.path.join(trace_dir, name)
        with jax.profiler.trace(path):
            yield
        self.emit(
            {
                "kind": "profile",
                "name": str(name),
                "trace_dir": path,
                "wall_time": time.time(),
            }
        )


class NullTracker(Tracker):
    def emit(self, event: Dict[str, Any]) -> None:
        pass


class MemoryTracker(Tracker):
    """In-memory event list — the test/inspection backend."""

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)


class JsonlTracker(Tracker):
    """Append-only JSONL event log (one event per line)."""

    def __init__(self, path: str) -> None:
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._fh: Optional[IO[str]] = open(path, "a")

    def emit(self, event: Dict[str, Any]) -> None:
        assert self._fh is not None, "JsonlTracker already finished"
        json.dump(event, self._fh, default=str)
        self._fh.write("\n")
        self._fh.flush()

    def finish(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


class CsvStdoutTracker(Tracker):
    """Prints ``row`` events in the harness's ``name,us_per_call,derived``
    CSV format (other event kinds are ignored)."""

    def __init__(self, stream: Optional[IO[str]] = None, *, header: bool = False) -> None:
        import sys

        self.stream = stream if stream is not None else sys.stdout
        if header:
            print("name,us_per_call,derived", file=self.stream)

    def emit(self, event: Dict[str, Any]) -> None:
        if event.get("kind") != "row":
            return
        print(
            f"{event['name']},{event['us_per_call']:.1f},{event['derived']}",
            file=self.stream,
        )


class CompositeTracker(Tracker):
    """Fan every event out to child backends."""

    def __init__(self, *trackers: Tracker) -> None:
        self.trackers: List[Tracker] = [t for t in trackers if t is not None]

    def emit(self, event: Dict[str, Any]) -> None:
        for t in self.trackers:
            t.emit(event)

    def finish(self) -> None:
        for t in self.trackers:
            t.finish()


def events_equal(a: Iterable[Mapping[str, Any]], b: Iterable[Mapping[str, Any]]) -> bool:
    """Compare event streams ignoring wall-clock and timer/span jitter."""

    def norm(events):
        out = []
        for e in events:
            e = {k: v for k, v in e.items()
                 if k not in ("wall_time", "seconds", "t0", "t1")}
            out.append(json.loads(json.dumps(e, default=str)))
        return out

    return norm(a) == norm(b)
