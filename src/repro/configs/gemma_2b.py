"""gemma-2b [dense] — GeGLU, MQA (kv=1), head_dim=256.

Assigned: 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=256000
[arXiv:2403.08295].
"""
from repro.models.config import ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    arch_id="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    block_pattern=uniform_pattern("attn", 18),
    mlp_kind="geglu",
    tie_embeddings=True,
    long_context_window=8192,
    notes="GeGLU, head_dim=256, MQA [arXiv:2403.08295]",
)


def smoke_config():
    return ModelConfig(
        arch_id="gemma-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=uniform_pattern("attn", 2),
        mlp_kind="geglu",
        tie_embeddings=True,
    )
