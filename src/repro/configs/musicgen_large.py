"""musicgen-large [audio] — decoder-only over EnCodec tokens.

Assigned: 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048
[arXiv:2306.05284]. 4 EnCodec codebooks (delay pattern not modelled);
codebook embeddings are summed, 4 output heads. The mel/EnCodec frontend is
a stub — input_specs() provides token streams directly (DESIGN.md §4).
"""
from repro.models.config import ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    arch_id="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=uniform_pattern("attn", 48),
    mlp_kind="gelu",
    num_codebooks=4,
    long_context_window=8192,
    notes="decoder-only over EnCodec tokens [arXiv:2306.05284]",
)


def smoke_config():
    return ModelConfig(
        arch_id="musicgen-smoke",
        family="audio",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
        block_pattern=uniform_pattern("attn", 2),
        mlp_kind="gelu",
        num_codebooks=4,
    )
