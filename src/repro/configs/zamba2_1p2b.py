"""zamba2-1.2b [hybrid] — Mamba2 backbone + weight-shared attention blocks.

Assigned: 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000 ssm_state=64
[arXiv:2411.15242]. Pattern: every 6th slot is the single weight-tied
attention+MLP block (zamba2's shared transformer block); the rest are Mamba2.
Subquadratic at 500k: Mamba2 state is O(1); the shared attention slots use
the sliding-window override at long context (DESIGN.md §4).
"""
from repro.models.config import MambaConfig, ModelConfig


def _pattern(n):
    return tuple("shared" if (i % 6) == 5 else "mamba" for i in range(n))


CONFIG = ModelConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    block_pattern=_pattern(38),
    mlp_kind="swiglu",
    mamba=MambaConfig(state_dim=64, head_dim=64, expand=2, chunk=256, conv_width=4),
    sliding_window=4096,
    long_context_window=4096,
    subquadratic=True,
    notes="Mamba2 + shared attn blocks [arXiv:2411.15242]",
)


def smoke_config():
    return ModelConfig(
        arch_id="zamba2-smoke",
        family="hybrid",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=("mamba", "shared"),
        mlp_kind="swiglu",
        mamba=MambaConfig(state_dim=16, head_dim=32, expand=2, chunk=32, conv_width=4),
        subquadratic=True,
    )
