"""gemma3-1b [dense] — 5:1 local:global sliding-window attention, 128k ctx.

Assigned: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144
[hf:google/gemma-3-1b-pt]. Local layers use window=512 (gemma3-1b card);
every 6th layer is global. Natively long-context capable: only the 1-in-6
global layers hold a full-length cache at 500k.
"""
from repro.models.config import ModelConfig

_PATTERN = tuple("attn" if (i % 6) == 5 else "attn_local" for i in range(26))

CONFIG = ModelConfig(
    arch_id="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    block_pattern=_PATTERN,
    mlp_kind="geglu",
    tie_embeddings=True,
    rope_theta=1e6,
    sliding_window=512,
    subquadratic=True,  # 5:1 local + O(1)-per-step global decode
    notes="5:1 local:global, 128k [hf:google/gemma-3-1b-pt]",
)


def smoke_config():
    return ModelConfig(
        arch_id="gemma3-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=("attn_local", "attn"),
        mlp_kind="geglu",
        tie_embeddings=True,
        sliding_window=16,
        subquadratic=True,
    )
