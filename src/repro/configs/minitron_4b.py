"""minitron-4b [dense] — pruned nemotron (squared-ReLU MLP).

Assigned: 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000
[arXiv:2407.14679]. head_dim=128.
"""
from repro.models.config import ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    arch_id="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    block_pattern=uniform_pattern("attn", 32),
    mlp_kind="relu2",
    long_context_window=8192,
    notes="pruned nemotron, squared-ReLU [arXiv:2407.14679]",
)


def smoke_config():
    return ModelConfig(
        arch_id="minitron-smoke",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=uniform_pattern("attn", 2),
        mlp_kind="relu2",
    )
