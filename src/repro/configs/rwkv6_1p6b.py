"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.

Assigned: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
[arXiv:2404.05892]. head_dim=64 (32 heads), decay LoRA rank 64.
Natively O(1)-state at any context length.
"""
from repro.models.config import ModelConfig, RWKVConfig, uniform_pattern

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,  # derived: d_model / head_dim (attn-free; used for state layout)
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=uniform_pattern("rwkv", 24),
    mlp_kind="relu2",
    use_rope=False,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
    subquadratic=True,
    notes="Finch — data-dependent decay [arXiv:2404.05892]",
)


def smoke_config():
    return ModelConfig(
        arch_id="rwkv6-smoke",
        family="ssm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=uniform_pattern("rwkv", 2),
        mlp_kind="relu2",
        use_rope=False,
        rwkv=RWKVConfig(head_dim=32, decay_lora=16),
        subquadratic=True,
    )
