"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared / 160 routed top-6.

Assigned: 60L d_model=5120 128H (kv=128) d_ff=1536 vocab=102400, MoE 160e
top-6 [arXiv:2405.04434]. Layer 0 uses a dense FFN (d_ff_dense = 12288,
the DeepSeek-V2 first-layer width); layers 1..59 are MoE with per-expert
d_ff = 1536 and 2 shared experts. MLA: kv_lora_rank=512, rope_head_dim=64,
nope/v head dims 128.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=12288,  # dense first layer
    vocab_size=102400,
    block_pattern=("attn",) + tuple(["moe"] * 59),
    mlp_kind="swiglu",
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(
        num_experts=160, top_k=6, d_ff_expert=1536, num_shared=2, d_ff_shared=1536,
        capacity_factor=1.25,
    ),
    long_context_window=8192,
    notes="MLA kv_lora=512, 2 shared + 160 routed top-6 [arXiv:2405.04434]",
)


def smoke_config():
    return ModelConfig(
        arch_id="deepseek-v2-smoke",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=("attn", "moe"),
        mlp_kind="swiglu",
        mla=MLAConfig(kv_lora_rank=32, rope_head_dim=16, nope_head_dim=32, v_head_dim=32),
        # ample capacity: smoke tests check decode==prefill exactly (no drops)
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, num_shared=1, d_ff_shared=64,
                      capacity_factor=8.0),
    )
