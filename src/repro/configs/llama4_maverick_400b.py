"""llama4-maverick-400b-a17b [moe] — 128e top-1 MoE, alternating dense/MoE.

Assigned: 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e
top-1 [hf:meta-llama/Llama-4-Scout-17B-16E family]. Alternating
dense/MoE layers with a shared expert (llama4 interleave); early-fusion
multimodality is stubbed (text tokens only in input_specs — DESIGN.md §4).
Uses iRoPE-style chunked-local attention for the long_500k variant.
"""
from repro.models.config import ModelConfig, MoEConfig, periodic_pattern

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=periodic_pattern(("attn", "moe"), 48),
    mlp_kind="swiglu",
    rope_theta=5e5,
    moe=MoEConfig(
        num_experts=128, top_k=1, d_ff_expert=8192, num_shared=1, d_ff_shared=8192,
        capacity_factor=1.25,
    ),
    long_context_window=8192,
    notes="MoE 128e top-1, early fusion (stub) [hf:meta-llama/Llama-4]",
)


def smoke_config():
    return ModelConfig(
        arch_id="llama4-smoke",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=("attn", "moe"),
        mlp_kind="swiglu",
        # ample capacity: smoke tests check decode==prefill exactly (no drops)
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=128, num_shared=1, d_ff_shared=128,
                      capacity_factor=8.0),
    )
