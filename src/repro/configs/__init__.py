"""Architecture registry: ``get(arch_id)`` / ``get_smoke(arch_id)`` / ``ARCHS``.

Each ``<id>.py`` exports ``CONFIG`` (the exact assigned configuration, source
cited in its docstring) and ``smoke_config()`` (a reduced same-family variant:
<= 2 layers, d_model <= 512, <= 4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "zamba2_1p2b",
    "starcoder2_7b",
    "gemma_2b",
    "deepseek_v2_236b",
    "musicgen_large",
    "llama4_maverick_400b",
    "gemma3_1b",
    "pixtral_12b",
    "rwkv6_1p6b",
    "minitron_4b",
]

# CLI ids (as assigned) -> module names
ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "starcoder2-7b": "starcoder2_7b",
    "gemma-2b": "gemma_2b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "musicgen-large": "musicgen_large",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "gemma3-1b": "gemma3_1b",
    "pixtral-12b": "pixtral_12b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "minitron-4b": "minitron_4b",
}


def _module(arch_id: str):
    name = ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "p"))
    return importlib.import_module(f"repro.configs.{name}")


def get(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str):
    return _module(arch_id).smoke_config()
