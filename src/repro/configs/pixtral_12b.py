"""pixtral-12b [vlm] — mistral-nemo decoder consuming pixtral-ViT patches.

Assigned: 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409]. The ViT tower + projector is a stub:
input_specs() supplies 1024 precomputed patch embeddings [B, 1024, D]
prepended to the text tokens (DESIGN.md §4).
"""
from repro.models.config import ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    arch_id="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=14336,
    vocab_size=131072,
    block_pattern=uniform_pattern("attn", 40),
    mlp_kind="swiglu",
    rope_theta=1e6,
    num_patches=1024,
    long_context_window=8192,
    notes="pixtral-ViT (stub) + mistral-nemo decoder [hf:mistralai/Pixtral-12B-2409]",
)


def smoke_config():
    return ModelConfig(
        arch_id="pixtral-smoke",
        family="vlm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=uniform_pattern("attn", 2),
        mlp_kind="swiglu",
        num_patches=16,
    )
