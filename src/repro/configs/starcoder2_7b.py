"""starcoder2-7b [dense] — GQA + RoPE code model.

Assigned: 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152
[arXiv:2402.19173]. head_dim = 4608/36 = 128. Full attention; long_500k
runs under the sliding-window variant (long_context_window).
"""
from repro.models.config import ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    arch_id="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    block_pattern=uniform_pattern("attn", 32),
    mlp_kind="gelu",
    rope_theta=1e5,
    long_context_window=8192,
    notes="GQA, RoPE [arXiv:2402.19173]",
)


def smoke_config():
    return ModelConfig(
        arch_id="starcoder2-smoke",
        family="dense",
        num_layers=2,
        d_model=144,
        num_heads=6,
        num_kv_heads=2,
        head_dim=24,
        d_ff=288,
        vocab_size=512,
        block_pattern=uniform_pattern("attn", 2),
        mlp_kind="gelu",
    )
