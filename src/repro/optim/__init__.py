from .optimizers import adamw_init, adamw_update, sgd_init, sgd_update, Optimizer, make_optimizer  # noqa: F401
from .schedules import constant_lr, cosine_warmup, make_schedule  # noqa: F401
