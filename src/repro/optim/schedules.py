"""Learning-rate schedules (paper §1: StepLR-style substrate) ."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(peak_lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return fn


def inv_sqrt(gamma0: float):
    """The paper's decreasing schedule (15)/(25) as an LR schedule."""
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        return gamma0 / jnp.sqrt(s + 1.0)

    return fn


def make_schedule(spec: str, **kw):
    parts = spec.split(":")
    if parts[0] == "constant":
        return constant_lr(float(parts[1]))
    if parts[0] == "cosine":
        return cosine_warmup(float(parts[1]), int(kw.get("warmup", 100)), int(kw.get("total", 10000)))
    if parts[0] == "inv_sqrt":
        return inv_sqrt(float(parts[1]))
    raise ValueError(spec)
