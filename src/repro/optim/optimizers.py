"""Optimizers (built here — no optax in the environment).

Pure-functional: ``init(params) -> state``, ``update(grads, state, params,
lr) -> (new_params, new_state)``. The server-side master update of the
MARINA-P/EF21-P trainer runs these on fp32 master weights (ZeRO-1-style fsdp
sharding of the moments — see launch/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def _tree_zeros_like(tree):
    return jax.tree.map(lambda t: jnp.zeros_like(t, dtype=jnp.float32), tree)


# -- SGD (+ momentum) ---------------------------------------------------------


def sgd_init(params, momentum: float = 0.0):
    return {"mu": _tree_zeros_like(params)} if momentum else {}


def sgd_update(grads, state, params, lr, *, momentum: float = 0.0, weight_decay: float = 0.0):
    if momentum:
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads)
        step_dir = mu
        new_state = {"mu": mu}
    else:
        step_dir = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_state = {}
    new_params = jax.tree.map(
        lambda p, d: (p - lr * (d + weight_decay * p.astype(jnp.float32))).astype(p.dtype),
        params,
        step_dir,
    )
    return new_params, new_state


# -- AdamW --------------------------------------------------------------------


def adamw_init(params):
    return {
        "m": _tree_zeros_like(params),
        "v": _tree_zeros_like(params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0
):
    count = state["count"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
    bc1 = 1 - b1**count.astype(jnp.float32)
    bc2 = 1 - b2**count.astype(jnp.float32)
    def upd(p, m_, v_):
        step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return (p - lr * (step + weight_decay * p.astype(jnp.float32))).astype(p.dtype)
    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}


# -- registry -----------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (params, state)
    name: str


def make_optimizer(spec: str, **kw) -> Optimizer:
    """``adamw``, ``sgd``, ``sgd:0.9`` (momentum)."""
    parts = spec.split(":")
    if parts[0] == "adamw":
        return Optimizer(
            init=adamw_init,
            update=lambda g, s, p, lr: adamw_update(g, s, p, lr, **kw),
            name="adamw",
        )
    if parts[0] == "sgd":
        mom = float(parts[1]) if len(parts) > 1 else kw.pop("momentum", 0.0)
        return Optimizer(
            init=lambda p: sgd_init(p, mom),
            update=lambda g, s, p, lr: sgd_update(g, s, p, lr, momentum=mom, **kw),
            name="sgd",
        )
    raise ValueError(spec)
