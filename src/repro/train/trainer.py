"""Training loop wiring: model + optimizer + compressed downlink.

One MARINA-P round per train step (uplink exact, downlink compressed):

    workers:  g_i = grad_{w_i} loss(w_i, batch_i)      [vmap over W axis]
    server:   g = mean_i g_i                           [all-reduce]
              x_new, opt = optimizer(g, x, lr)         [fp32 master, ZeRO-1]
    downlink: w_i += Q_i(x_new - x)  or full sync      [compressed broadcast]

``downlink=None`` is the exact-broadcast baseline (classic data-parallel:
w_i = x always). ``EF21PDownlink`` keeps one synchronized shift tree.
Polyak adaptive LR (the paper's (13), with f* estimate) is available as
``polyak=...`` — it consumes only quantities already on the server.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.comm_model import CommModel
from repro.models import lm
from repro.models.config import ModelConfig
from repro.obs.trace import maybe_attr, span
from repro.optim import Optimizer
from .downlink import EF21PDownlink, MarinaPDownlink, tree_size


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    n_workers: int = 4
    remat: bool = True
    attn_chunk: int = 512
    weight_dtype: Any = jnp.float32       # worker replica dtype
    polyak_factor: float = 0.0            # >0: Polyak LR instead of schedule
    polyak_f_star: float = 0.0
    window_override: Optional[int] = None
    remat_policy: Optional[str] = None    # None/"full" | "dots" (§Perf C2)
    act_spec: Any = None                  # within-worker activation spec (§Perf C3)
    drop_prob: float = 0.0                # legacy shim over BernoulliStragglerPlan
    straggler_cutoff: float = 0.0         # legacy shim over BernoulliStragglerPlan
    participation: Any = None             # repro.fleet ParticipationPlan (None = full)


def init_state(cfg: ModelConfig, tcfg: TrainerConfig, downlink, optimizer: Optimizer, key):
    server = lm.lm_init(cfg, key)
    state = {
        "server": server,
        "opt": optimizer.init(server),
        "step": jnp.zeros((), jnp.int32),
        "bits_per_worker": jnp.zeros((), jnp.float32),
        "uplink_bits_per_worker": jnp.zeros((), jnp.float32),
    }
    if downlink is not None:
        workers = downlink.init_workers(server)
        state["workers"] = jax.tree.map(lambda t: t.astype(tcfg.weight_dtype), workers)
    return state


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainerConfig,
    downlink,
    optimizer: Optimizer,
    lr_fn: Callable,
):
    """Returns jittable (state, batch, key) -> (state, metrics).

    batch leaves have a leading worker axis [W, B_local, ...].
    """

    def loss_of(params, shard):
        return lm.loss_fn(
            cfg, params, shard,
            chunk=tcfg.attn_chunk, remat=tcfg.remat,
            window_override=tcfg.window_override,
            remat_policy=tcfg.remat_policy,
            act_spec=tcfg.act_spec,
        )

    grad_fn = jax.value_and_grad(loss_of)

    # Participation is a single pluggable hook (repro.fleet.ParticipationPlan).
    # The legacy drop_prob/straggler_cutoff knobs are thin shims over
    # BernoulliStragglerPlan — op-for-op identical to the old inline branch,
    # so legacy configs stay bit-identical to their plan equivalents.
    from repro.fleet.sampler import PARTICIPATION_FOLD, plan_from_legacy

    plan = tcfg.participation
    if plan is not None and (tcfg.drop_prob > 0 or tcfg.straggler_cutoff > 0):
        raise ValueError(
            "TrainerConfig.participation and the legacy drop_prob/"
            "straggler_cutoff knobs are mutually exclusive; the legacy knobs "
            "are shims over BernoulliStragglerPlan — set one or the other."
        )
    if plan is None:
        plan = plan_from_legacy(tcfg.drop_prob, tcfg.straggler_cutoff)
    partial = not plan.is_full

    def train_step(state, batch, key, force_sync=False):
        server = state["server"]
        # ---- workers: forward/backward on their own replica -----------------
        if downlink is None:
            losses, grads_w = jax.vmap(lambda shard: grad_fn(server, shard))(batch)
        elif isinstance(downlink, EF21PDownlink):
            shift = state["workers"]
            losses, grads_w = jax.vmap(lambda shard: grad_fn(shift, shard))(batch)
        else:
            workers = state["workers"]
            losses, grads_w = jax.vmap(grad_fn)(workers, batch)
        # ---- uplink: exact aggregation over the round's participants ---------
        # Partial participation (DESIGN.md §8.5/§9.2): the plan maps a
        # participation key to this round's worker mask. Only the uplink
        # aggregation is masked — the downlink still addresses everyone.
        # The participation key is folded off to the side
        # (fold_in(key, PARTICIPATION_FOLD)) so the downlink RNG stream is
        # bit-identical to the full-participation path, and every plan draws
        # from the same folded key so swapping plans never perturbs it.
        if partial:
            n = tcfg.n_workers
            k_part = jax.random.fold_in(key, PARTICIPATION_FOLD)
            participate = plan.mask(k_part, n, state["step"])
            n_part = jnp.maximum(jnp.sum(participate), 1)
            w = participate.astype(jnp.float32) / n_part
            grads = jax.tree.map(
                lambda g: jnp.tensordot(w, g.astype(jnp.float32), axes=1), grads_w
            )
            loss = jnp.sum(w * losses)
        else:
            grads = jax.tree.map(
                lambda g: jnp.mean(g.astype(jnp.float32), axis=0), grads_w
            )
            loss = jnp.mean(losses)
        gnorm_sq = sum(jnp.sum(g * g) for g in jax.tree.leaves(grads))
        # ---- server master update --------------------------------------------
        if tcfg.polyak_factor > 0:
            lr = tcfg.polyak_factor * jnp.maximum(loss - tcfg.polyak_f_star, 0.0) / jnp.maximum(gnorm_sq, 1e-20)
        else:
            lr = lr_fn(state["step"])
        server_new, opt_new = optimizer.update(grads, state["opt"], server, lr)
        # ---- uplink: exact dense gradient per worker (w2s, ROADMAP gap) ------
        d = tree_size(server)
        uplink_bits = state["uplink_bits_per_worker"] + CommModel(d=d).dense_bits()
        new_state = {
            "server": server_new,
            "opt": opt_new,
            "step": state["step"] + 1,
            "bits_per_worker": state["bits_per_worker"],
            "uplink_bits_per_worker": uplink_bits,
        }
        metrics = {"loss": loss, "grad_norm": jnp.sqrt(gnorm_sq), "lr": lr,
                   "uplink_bits_per_worker": uplink_bits}
        if partial:
            metrics["participants"] = jnp.sum(participate).astype(jnp.float32)
        # ---- downlink: compressed broadcast ----------------------------------
        if downlink is None:
            pass
        elif isinstance(downlink, EF21PDownlink):
            shift_new, bits = downlink.round(
                key, server_new, state["workers"], force_sync
            )
            new_state["workers"] = shift_new
            new_state["bits_per_worker"] = state["bits_per_worker"] + bits
            metrics["drift"] = downlink.worker_drift(server_new, shift_new)
        else:
            workers_new, bits = downlink.round(
                key, server_new, server, state["workers"], force_sync
            )
            new_state["workers"] = workers_new
            new_state["bits_per_worker"] = state["bits_per_worker"] + bits
            metrics["drift"] = downlink.worker_drift(server_new, workers_new)
        metrics["bits_per_worker"] = new_state["bits_per_worker"]
        return new_state, metrics

    return train_step


def train_loop(
    cfg: ModelConfig,
    tcfg: TrainerConfig,
    downlink,
    optimizer: Optimizer,
    lr_fn: Callable,
    data,
    *,
    steps: int,
    key,
    tracker=None,
    log_every: int = 1,
    transport=None,
    wire_mag: str = "fp32",
):
    """Host loop around the jitted step with per-step telemetry.

    Each step is timed with a ``block_until_ready``-correct host timer
    ("train/step") and its metrics (loss, grad_norm, lr, drift,
    bits_per_worker, uplink_bits_per_worker) are logged to ``tracker``
    at ``log_every`` cadence. Returns (final_state, last_metrics).

    ``transport`` (a :class:`repro.transport.Fleet` or a
    :class:`repro.transport.FaultSpec`) additionally pushes each round's
    downlink through fault-injected reliable links via the downlink's
    ``broadcast_via``; a round whose delivery degrades (undelivered
    worker or receiver resync request) promotes the *next* round's
    broadcast to a full sync, whose self-contained SYNC frame repairs
    every receiver (DESIGN.md §8.4). The last metrics dict then carries
    the fleet counters under ``"transport"``.
    """
    from repro import obs

    tracker = tracker or obs.NullTracker()
    k_init, k_steps = jax.random.split(key)
    state = init_state(cfg, tcfg, downlink, optimizer, k_init)
    step = jax.jit(make_train_step(cfg, tcfg, downlink, optimizer, lr_fn))
    fleet = None
    if transport is not None and downlink is not None:
        from repro.transport import FaultSpec, Fleet

        fleet = (
            Fleet.make(tcfg.n_workers, transport, timeout=2, max_retries=2)
            if isinstance(transport, FaultSpec)
            else transport
        )
    m = {}
    force_sync = False
    for i in range(steps):
        batch = data.batch(i)
        k_step = jax.random.fold_in(k_steps, i)
        prev_server = state["server"]
        prev_workers = state.get("workers")
        was_forced = force_sync
        with span(tracker, "round", round=i, alg="train") as rsp:
            with tracker.time_block("train/step", step=i) as tb:
                state, m = step(state, batch, k_step, force_sync)
                tb.block(m)
            if fleet is not None:
                if isinstance(downlink, EF21PDownlink):
                    res = downlink.broadcast_via(
                        fleet, k_step, state["server"], prev_workers,
                        mag=wire_mag, force_sync=force_sync, tracker=tracker,
                        step=i,
                    )
                else:
                    res = downlink.broadcast_via(
                        fleet, k_step, state["server"], prev_server,
                        mag=wire_mag, force_sync=force_sync, tracker=tracker,
                        step=i,
                    )
                force_sync = res["resync_needed"]
                maybe_attr(rsp, full_sync=res["full_sync"],
                           resync_next=force_sync)
            maybe_attr(rsp, force_sync=was_forced, loss=float(m["loss"]))
        if i % log_every == 0:
            tracker.log({"train": m}, step=i)
    if fleet is not None:
        m = dict(m)
        m["transport"] = fleet.stats().as_metrics()
    return state, m
