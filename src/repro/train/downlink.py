"""MARINA-P / EF21-P as the model-broadcast layer of LM training.

This is the paper's technique integrated as a first-class feature of the
training runtime: after the server (master) optimizer step, the *model delta*
broadcast to each data-parallel worker replica is compressed.

* :class:`MarinaPDownlink` — Algorithm 2 over parameter pytrees. Worker
  replicas are a leading ``W`` axis; broadcast modes:
    - ``perm``: RotK cyclic-partition PermK (omega = W-1, exact-mean identity)
    - ``ind`` : per-worker Bernoulli-K (omega = d/k - 1)
    - ``same``: shared Bernoulli-K mask
  With probability ``p`` the full model is synchronized (Bernoulli coin).
* :class:`EF21PDownlink` — Algorithm 1 over pytrees with block-TopK. The
  synchronized shift ``w`` is a single tree (all workers identical).

Both track the paper's analytic WAN bits per round (comm_model) as jnp
scalars inside the train state. On the TPU mesh itself the messages cost
zero interconnect bytes (shared-randomness materialization — DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm_model import CommModel
from repro.core.compressors import BlockTopK
from repro.obs.trace import maybe_attr, maybe_span

Array = jax.Array


def _leaf_rotk_mask(key, shape, n, worker):
    """RotK mask for one leaf: coordinate j kept iff j % n == (worker+r) % n."""
    size = math.prod(shape) if shape else 1
    r = jax.random.randint(key, (), 0, n)
    idx = jax.lax.iota(jnp.int32, size) % n
    return (idx == (worker + r) % n).reshape(shape)


def _leaf_bern_mask(key, shape, keep_prob):
    return jax.random.uniform(key, shape) < keep_prob


def tree_size(tree) -> int:
    return sum(math.prod(l.shape) if l.shape else 1 for l in jax.tree.leaves(tree))


def _use_device_encode(device_encode) -> bool:
    """Route a downlink serialization through kernels/encode.py?"""
    from repro.kernels import encode as kenc

    return kenc.device_encode_enabled(device_encode)


def _track_wire(tracker, step, res: dict) -> dict:
    """Log a measure_wire result as downlink/* metrics; returns ``res``."""
    if tracker is not None:
        tracker.log(
            {
                "downlink/wire_bits_mean": res["bits_mean"],
                "downlink/wire_bits_analytic": res["bits_analytic"],
                "downlink/full_sync": res["full_sync"],
                **(
                    {"downlink/wire_bits_seed": res["bits_seed"]}
                    if "bits_seed" in res
                    else {}
                ),
            },
            step=step,
        )
    return res


@dataclasses.dataclass(frozen=True)
class MarinaPDownlink:
    """Compressed server->worker model broadcast (Algorithm 2, pytree form)."""

    n_workers: int
    mode: str = "perm"          # perm | ind | same
    keep_frac: float = 0.0      # bern modes: k/d; default 1/n (PermK-parity)
    p: float = 0.0              # full-sync probability; default 1/n

    @property
    def sync_p(self) -> float:
        return self.p if self.p > 0 else 1.0 / self.n_workers

    @property
    def frac(self) -> float:
        if self.mode == "perm":
            return 1.0 / self.n_workers
        return self.keep_frac if self.keep_frac > 0 else 1.0 / self.n_workers

    def omega(self) -> float:
        if self.mode == "perm":
            return self.n_workers - 1.0
        return 1.0 / self.frac - 1.0

    def init_workers(self, server_params):
        """w_i^0 = x^0 for all i (leading worker axis)."""
        return jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (self.n_workers,) + t.shape), server_params
        )

    def round(self, key, server_new, server_old, worker_params, force_sync=False):
        """One downlink round -> (new worker params, bits/worker this round).

        The Bernoulli branch is a ``lax.cond`` so only one of
        {full-sync broadcast, compressed update} materializes per round
        (§Perf iteration C1 — jnp.where evaluated both, costing ~2x the
        downlink HBM traffic). ``force_sync`` promotes the round to the
        full broadcast unconditionally — the transport layer's resync
        path (DESIGN.md §8.4).
        """
        k_bern, k_comp = jax.random.split(key)
        c = jnp.logical_or(jax.random.bernoulli(k_bern, self.sync_p), force_sync)
        n = self.n_workers

        def sync_branch(operands):
            server_new, worker_params = operands
            return jax.tree.map(
                lambda xn, wp: jnp.broadcast_to(xn.astype(wp.dtype)[None], wp.shape),
                server_new,
                worker_params,
            )

        def compress_branch(operands):
            server_new, worker_params = operands
            leaves_new, treedef = jax.tree.flatten(server_new)
            leaves_old = jax.tree.leaves(server_old)
            leaves_w = jax.tree.leaves(worker_params)
            out = []
            for li, (xn, xo, wp) in enumerate(zip(leaves_new, leaves_old, leaves_w)):
                delta = (xn - xo).astype(wp.dtype)
                lk = jax.random.fold_in(k_comp, li)
                if self.mode == "perm":
                    def q_one(widx):
                        m = _leaf_rotk_mask(lk, xn.shape, n, widx)
                        return jnp.where(m, delta * n, 0)
                elif self.mode == "ind":
                    def q_one(widx):
                        m = _leaf_bern_mask(jax.random.fold_in(lk, widx), xn.shape, self.frac)
                        return jnp.where(m, delta / self.frac, 0)
                else:  # same
                    m_shared = _leaf_bern_mask(lk, xn.shape, self.frac)

                    def q_one(widx):
                        return jnp.where(m_shared, delta / self.frac, 0)

                out.append(wp + jax.vmap(q_one)(jnp.arange(n)))
            return jax.tree.unflatten(treedef, out)

        new_workers = jax.lax.cond(c, sync_branch, compress_branch,
                                   (server_new, worker_params))
        d = tree_size(server_new)
        cm = CommModel(d=d)  # single source of truth for the bit formulas
        bits = jnp.where(c, cm.dense_bits(), cm.sparse_bits(self.frac * d))
        return new_workers, bits

    def worker_drift(self, server_params, worker_params) -> Array:
        """mean_i ||w_i - x||^2 — the Lyapunov drift term of Theorem 2."""
        sq = jax.tree.map(
            lambda w, x: jnp.sum((w.astype(jnp.float32) - x.astype(jnp.float32)[None]) ** 2),
            worker_params,
            server_params,
        )
        return sum(jax.tree.leaves(sq)) / self.n_workers

    def _dense_buf(self, server_new, mag, device_encode=None):
        """Serialize the full model for a sync broadcast."""
        import jax.flatten_util  # noqa: F401  (registers jax.flatten_util)
        import numpy as np

        from repro import wire

        flat = jax.flatten_util.ravel_pytree(
            jax.tree.map(lambda t: t.astype(jnp.float32), server_new)
        )[0]
        if _use_device_encode(device_encode):
            from repro.kernels import encode as kenc

            return kenc.dense_encode(flat, mag=mag)
        return wire.encode_dense(np.asarray(flat), mag=mag)

    def _sparse_bufs(self, k_comp, server_new, server_old, mag,
                     device_encode=None):
        """Per-worker compressed-delta buffers, replaying :meth:`round`'s
        randomness over the raveled tree. 'same' mode encodes once and
        repeats the buffer (every worker's message is identical); the
        device path batches the per-worker rows through one vmapped
        encode (kernels/encode.encode_rows)."""
        import numpy as np

        from repro import wire

        n = self.n_workers
        leaves_new, _ = jax.tree.flatten(server_new)
        leaves_old = jax.tree.leaves(server_old)
        rows = []
        for widx in range(1 if self.mode == "same" else n):
            parts = []
            for li, (xn, xo) in enumerate(zip(leaves_new, leaves_old)):
                delta = (xn - xo).astype(jnp.float32)
                lk = jax.random.fold_in(k_comp, li)
                if self.mode == "perm":
                    m = _leaf_rotk_mask(lk, xn.shape, n, widx)
                    q = jnp.where(m, delta * n, 0)
                elif self.mode == "ind":
                    m = _leaf_bern_mask(jax.random.fold_in(lk, widx), xn.shape, self.frac)
                    q = jnp.where(m, delta / self.frac, 0)
                else:  # same
                    m = _leaf_bern_mask(lk, xn.shape, self.frac)
                    q = jnp.where(m, delta / self.frac, 0)
                parts.append(q.reshape(-1))
            rows.append(jnp.concatenate(parts))
        if _use_device_encode(device_encode):
            from repro.kernels import encode as kenc

            bufs = kenc.encode_rows(jnp.stack(rows), mag=mag)
        else:
            bufs = [wire.encode_sparse(np.asarray(r), mag=mag) for r in rows]
        if self.mode == "same":
            bufs = bufs * n
        return bufs

    def measure_wire(self, key, server_new, server_old, *, mag="fp32",
                     device_encode=None, tracker=None, step=None) -> dict:
        """Host-side wire measurement (measure_wire=True path).

        Replays this round's randomness exactly as :meth:`round` consumes it,
        rebuilds each worker's message over the raveled tree, and serializes
        it with the repro.wire codecs. Returns measured bits alongside the
        analytic model's prediction (value_bits matched to ``mag``) and the
        O(1) seed-only alternative (DESIGN.md §3.5). Not jittable — this is
        the accounting/verification path, not the training hot path.
        ``device_encode`` routes serialization through the fused Pallas
        encode kernels (byte-identical; None defers to
        ``REPRO_DEVICE_ENCODE``/backend auto-detect). ``tracker`` logs the
        result as a ``downlink/*`` metric event.
        """
        import numpy as np

        from repro import wire

        n = self.n_workers
        d = tree_size(server_new)
        cm = CommModel(d=d, value_bits=wire.MAG_BITS[wire.mag_dtype(mag)])
        k_bern, k_comp = jax.random.split(key)
        c = bool(jax.random.bernoulli(k_bern, self.sync_p))
        seed_buf = wire.encode_seed(
            wire.SeedMessage(
                family=wire.SeedFamily.ROTK if self.mode == "perm" else wire.SeedFamily.BERN,
                seed=int(np.asarray(
                    jax.random.key_data(k_comp)
                    if jnp.issubdtype(k_comp.dtype, jax.dtypes.prng_key)
                    else k_comp
                ).ravel()[-1]),
                round=0, scale=1.0, n=n, worker=0, param=self.frac,
            ),
            d,
        )
        if c:
            bits = float(wire.measured_bits(
                self._dense_buf(server_new, mag, device_encode)))
            return _track_wire(tracker, step, {
                "full_sync": True, "bits_mean": bits, "bits_per_worker": [bits] * n,
                "bits_seed": float(wire.measured_bits(seed_buf)),
                "bits_analytic": cm.dense_bits()})
        per_worker = [
            float(wire.measured_bits(buf))
            for buf in self._sparse_bufs(k_comp, server_new, server_old, mag,
                                         device_encode)
        ]
        return _track_wire(tracker, step, {
            "full_sync": False,
            "bits_mean": sum(per_worker) / n,
            "bits_per_worker": per_worker,
            "bits_seed": float(wire.measured_bits(seed_buf)),
            "bits_analytic": cm.sparse_bits(self.frac * d),
        })

    def broadcast_via(self, fleet, key, server_new, server_old, *, mag="fp32",
                      device_encode=None, force_sync=False, tracker=None,
                      step=None) -> dict:
        """Push this round's broadcast through a :class:`repro.transport.Fleet`.

        Replays the same randomness :meth:`round` consumed (pass the same
        ``key`` and ``force_sync``), serializes the actual per-worker
        messages, and delivers them over the fault-injected links. Sync
        rounds travel as self-contained SYNC frames (they repair any
        receiver gap). Returns per-worker delivery flags plus whether the
        *next* round must be promoted to a full sync (DESIGN.md §8.4).
        """
        k_bern, k_comp = jax.random.split(key)
        c = bool(jax.random.bernoulli(k_bern, self.sync_p)) or bool(force_sync)
        if tracker is not None:
            fleet.attach_tracker(tracker)
        with maybe_span(tracker, "broadcast", full_sync=c) as bsp:
            with maybe_span(tracker, "encode",
                            device=_use_device_encode(device_encode)):
                if c:
                    payloads = [self._dense_buf(server_new, mag, device_encode)]
                else:
                    payloads = self._sparse_bufs(
                        k_comp, server_new, server_old, mag, device_encode)
            if c:
                oks = fleet.broadcast(payloads[0], sync=True)
            else:
                oks = fleet.send_per_worker(payloads)
            fleet.drain()
            res = {
                "full_sync": c,
                "oks": oks,
                "delivered_frac": sum(oks) / len(oks),
                "resync_needed": fleet.resync_needed or not all(oks),
            }
            maybe_attr(bsp, delivered=int(sum(oks)),
                       resync_next=res["resync_needed"])
        if tracker is not None:
            tracker.log(
                {
                    "downlink/full_sync": c,
                    "downlink/delivered_frac": res["delivered_frac"],
                },
                step=step,
            )
            fleet.log_to(tracker, step=step)
        return res


@dataclasses.dataclass(frozen=True)
class EF21PDownlink:
    """EF21-P over pytrees with block-local TopK (Algorithm 1, pytree form)."""

    n_workers: int
    k_per_block: int = 128
    block: int = 1024

    @property
    def comp(self) -> BlockTopK:
        return BlockTopK(k_per_block=self.k_per_block, block=self.block)

    def init_shift(self, server_params):
        """w^0 = x^0; one tree — workers stay synchronized by construction."""
        return jax.tree.map(lambda t: t, server_params)

    def round(self, key, server_new, shift, force_sync=False):
        """``force_sync`` re-anchors the shift with a dense ``w := x``
        broadcast — the transport layer's resync path (DESIGN.md §8.4)."""
        comp = self.comp
        new_shift = jax.tree.map(
            lambda xn, w: jnp.where(
                force_sync,
                xn.astype(w.dtype),
                w + comp(None, (xn.astype(jnp.float32) - w.astype(jnp.float32)).reshape(-1)).reshape(w.shape).astype(w.dtype),
            ),
            server_new,
            shift,
        )
        d = tree_size(server_new)
        frac = self.k_per_block / self.block
        cm = CommModel(d=d)
        bits = jnp.where(force_sync, cm.dense_bits(), cm.sparse_bits(frac * d))
        return new_shift, bits

    def init_workers(self, server_params):
        return self.init_shift(server_params)

    def _delta_buf(self, server_new, shift, mag, device_encode=None):
        """Serialize the block-TopK compressed difference over the raveled
        tree (the broadcast message, identical for every worker)."""
        import numpy as np

        from repro import wire

        comp = self.comp
        parts = [
            comp(None, (xn.astype(jnp.float32) - w.astype(jnp.float32)).reshape(-1))
            for xn, w in zip(jax.tree.leaves(server_new), jax.tree.leaves(shift))
        ]
        delta = jnp.concatenate(parts)
        if _use_device_encode(device_encode):
            from repro.kernels import encode as kenc

            return kenc.sparse_encode(delta, mag=mag)
        return wire.encode_sparse(np.asarray(delta), mag=mag)

    def measure_wire(self, key, server_new, shift, *, mag="fp32",
                     device_encode=None, tracker=None, step=None) -> dict:
        """Host-side wire measurement of one EF21-P broadcast (the block-TopK
        compressed difference, identical for every worker)."""
        from repro import wire

        d = tree_size(server_new)
        cm = CommModel(d=d, value_bits=wire.MAG_BITS[wire.mag_dtype(mag)])
        buf = self._delta_buf(server_new, shift, mag, device_encode)
        frac = self.k_per_block / self.block
        return _track_wire(tracker, step, {
            "full_sync": False,
            "bits_mean": float(wire.measured_bits(buf)),
            "bits_per_worker": [float(wire.measured_bits(buf))] * self.n_workers,
            "bits_analytic": cm.sparse_bits(frac * d),
        })

    def broadcast_via(self, fleet, key, server_new, shift, *, mag="fp32",
                      device_encode=None, force_sync=False, tracker=None,
                      step=None) -> dict:
        """Deliver one EF21-P broadcast through a transport Fleet.

        A sync round ships the full model (``w := x`` re-anchor) as a
        self-contained SYNC frame; otherwise the block-TopK compressed
        difference, identical for every worker. ``resync_needed`` in the
        result means the caller must pass ``force_sync=True`` to the next
        :meth:`round` (and roll its shift back — DESIGN.md §8.4).
        """
        import jax.flatten_util  # noqa: F401
        import numpy as np

        from repro import wire

        if tracker is not None:
            fleet.attach_tracker(tracker)
        with maybe_span(tracker, "broadcast",
                        full_sync=bool(force_sync)) as bsp:
            with maybe_span(tracker, "encode",
                            device=_use_device_encode(device_encode)):
                if force_sync:
                    flat = jax.flatten_util.ravel_pytree(
                        jax.tree.map(
                            lambda t: t.astype(jnp.float32), server_new)
                    )[0]
                    if _use_device_encode(device_encode):
                        from repro.kernels import encode as kenc

                        buf = kenc.dense_encode(flat, mag=mag)
                    else:
                        buf = wire.encode_dense(np.asarray(flat), mag=mag)
                else:
                    buf = self._delta_buf(server_new, shift, mag,
                                          device_encode)
            oks = fleet.broadcast(buf, sync=bool(force_sync))
            fleet.drain()
            res = {
                "full_sync": bool(force_sync),
                "oks": oks,
                "delivered_frac": sum(oks) / len(oks),
                "resync_needed": fleet.resync_needed or not all(oks),
            }
            maybe_attr(bsp, delivered=int(sum(oks)),
                       resync_next=res["resync_needed"])
        if tracker is not None:
            tracker.log(
                {
                    "downlink/full_sync": res["full_sync"],
                    "downlink/delivered_frac": res["delivered_frac"],
                },
                step=step,
            )
            fleet.log_to(tracker, step=step)
        return res

    def worker_drift(self, server_params, shift) -> Array:
        sq = jax.tree.map(
            lambda w, x: jnp.sum((w.astype(jnp.float32) - x.astype(jnp.float32)) ** 2),
            shift,
            server_params,
        )
        return sum(jax.tree.leaves(sq))


def make_downlink(spec: str, n_workers: int):
    """``marina:perm``, ``marina:ind:0.0625``, ``marina:same``, ``ef21p:128:1024``,
    ``none`` (exact broadcast baseline)."""
    parts = spec.split(":")
    if parts[0] == "none":
        return None
    if parts[0] == "marina":
        mode = parts[1] if len(parts) > 1 else "perm"
        keep = float(parts[2]) if len(parts) > 2 else 0.0
        return MarinaPDownlink(n_workers=n_workers, mode=mode, keep_frac=keep)
    if parts[0] == "ef21p":
        kb = int(parts[1]) if len(parts) > 1 else 128
        b = int(parts[2]) if len(parts) > 2 else 1024
        return EF21PDownlink(n_workers=n_workers, k_per_block=kb, block=b)
    raise ValueError(spec)
