from .downlink import EF21PDownlink, MarinaPDownlink, make_downlink  # noqa: F401
from .trainer import TrainerConfig, init_state, make_train_step, train_loop  # noqa: F401
