"""Participation scheduling (DESIGN.md §9.2).

Two layers, both deterministic from a seed:

* **Slot plans** (:class:`ParticipationPlan`) — jittable masks over a
  fixed worker axis. These are the single participation hook the trainer
  and the core ``marina_p.run`` / ``ef21p.run`` loops consume: each round
  the caller folds a participation key *off the main RNG stream*
  (``fold_in(key, 0x5052)`` — the §8.5 key discipline, so the downlink
  stream is bit-identical with and without partial participation) and the
  plan maps it to a boolean mask. The legacy
  ``TrainerConfig.drop_prob`` / ``straggler_cutoff`` knobs are thin shims
  over :class:`BernoulliStragglerPlan`, which reproduces the old inline
  branch op-for-op so identical seeds give identical cohorts.

* **Cohort samplers** (:class:`CohortSampler`) — host-side schedulers
  that draw per-round cohorts of *client ids* from a declarative
  :class:`~repro.fleet.population.FleetSpec` population. Sampling is
  rejection-based (propose a uniform id, accept per scheduler policy), so
  a round costs O(cohort), never O(population). Schedulers: uniform,
  size-weighted (importance ∝ local dataset size), availability-window,
  and straggler-deadline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .population import FleetSpec

# the trainer's participation fold constant (DESIGN.md §8.5/§9.2): plans
# receive fold_in(step_key, PARTICIPATION_FOLD), never the main key
PARTICIPATION_FOLD = 0x5052


# ---------------------------------------------------------------------------
# Slot plans (jittable masks over a fixed worker axis)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParticipationPlan:
    """Maps (participation key, n slots, round t) -> bool mask [n].

    ``mask`` must be traceable (it runs inside the jitted train step);
    ``t`` may be a traced int32. ``is_full`` lets callers skip the masked
    aggregation path entirely (bit-identical to no plan at all).
    """

    @property
    def is_full(self) -> bool:
        return False

    def mask(self, key, n: int, t):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FullParticipation(ParticipationPlan):
    """Every slot participates every round (the classic full-sync setting)."""

    @property
    def is_full(self) -> bool:
        return True

    def mask(self, key, n, t):
        import jax.numpy as jnp

        return jnp.ones((n,), bool)


@dataclasses.dataclass(frozen=True)
class BernoulliStragglerPlan(ParticipationPlan):
    """The legacy ``drop_prob`` / ``straggler_cutoff`` model as a plan.

    Op-for-op identical to the pre-plan inline branch in
    ``train/trainer.py``: split the participation key into (drop,
    latency); a slot sits out with probability ``drop_prob`` and/or when
    its Exp(1) latency draw exceeds ``straggler_cutoff``. Keeping the ops
    identical is what makes legacy configs bit-identical to their plan
    equivalents (the regression test pins this).
    """

    drop_prob: float = 0.0
    straggler_cutoff: float = 0.0

    def mask(self, key, n, t):
        import jax
        import jax.numpy as jnp

        k_drop, k_lat = jax.random.split(key)
        m = jnp.ones((n,), bool)
        if self.drop_prob > 0:
            m &= jax.random.uniform(k_drop, (n,)) >= self.drop_prob
        if self.straggler_cutoff > 0:
            m &= jax.random.exponential(k_lat, (n,)) <= self.straggler_cutoff
        return m


@dataclasses.dataclass(frozen=True)
class AvailabilityWindowPlan(ParticipationPlan):
    """Deterministic diurnal windows over the slot axis: slot ``i`` is in
    its window when ``(t + phases[i]) mod period < open_ticks``."""

    phases: Tuple[int, ...] = ()
    period: int = 24
    open_ticks: int = 12

    @classmethod
    def for_slots(cls, spec: FleetSpec, n: int) -> "AvailabilityWindowPlan":
        """Phases hashed from a FleetSpec's availability trace."""
        phases = tuple(int(p) for p in spec.phase(np.arange(n)))
        a = spec.availability
        return cls(phases=phases, period=max(1, a.period), open_ticks=a.open_ticks)

    def mask(self, key, n, t):
        import jax.numpy as jnp

        assert len(self.phases) == n, (len(self.phases), n)
        ph = jnp.asarray(self.phases, jnp.int32)
        return ((t + ph) % self.period) < self.open_ticks


@dataclasses.dataclass(frozen=True)
class CyclingMaskPlan(ParticipationPlan):
    """Cycle through a fixed tuple of masks by round — test/repro helper
    for exact cohort patterns (e.g. an empty or size-1 round)."""

    masks: Tuple[Tuple[bool, ...], ...] = ((True,),)

    def mask(self, key, n, t):
        import jax.numpy as jnp

        table = jnp.asarray(self.masks, bool)
        assert table.shape[1] == n, (table.shape, n)
        return table[t % table.shape[0]]


def plan_from_legacy(drop_prob: float = 0.0, straggler_cutoff: float = 0.0) -> ParticipationPlan:
    """The shim the legacy trainer knobs route through."""
    if drop_prob <= 0 and straggler_cutoff <= 0:
        return FullParticipation()
    return BernoulliStragglerPlan(drop_prob=drop_prob, straggler_cutoff=straggler_cutoff)


# ---------------------------------------------------------------------------
# Cohort samplers (host-side client-id scheduling)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Cohort:
    """One round's sampled cohort: fixed-width slots for jit stability.

    ``ids[i]`` is slot i's client id; ``active[i]`` marks filled slots
    that made the round (unfilled slots and deadline-missed stragglers are
    inactive); ``weights`` are aggregation weights (uniform over active —
    size-weighted samplers bias the *sampling* probability instead, the
    importance-sampling form of FedAvg weighting).
    """

    ids: np.ndarray      # int64 [c]
    active: np.ndarray   # bool [c]
    weights: np.ndarray  # float64 [c], zero where inactive

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def fill(self) -> float:
        return self.n_active / max(len(self.ids), 1)


@dataclasses.dataclass(frozen=True)
class CohortSampler:
    """Base scheduler: uniform-without-replacement via rejection sampling.

    ``cohort(t)`` draws from ``default_rng((seed, SALT, t))`` so cohorts
    are deterministic per (sampler seed, round) and independent across
    rounds. Subclasses refine ``_accept`` (per-candidate policy) and
    ``_finalize`` (post-selection masking, e.g. deadline cuts). The draw
    budget bounds worst-case work at O(cohort * max_draw_factor).
    """

    spec: FleetSpec
    cohort_size: int
    seed: int = 0
    max_draw_factor: int = 128

    _SALT = 0x636F686F

    def rng(self, t: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, self._SALT, int(t)))

    def _accept(self, rng: np.random.Generator, cid: int, t: int) -> bool:
        return True

    def _finalize(self, rng: np.random.Generator, cohort: "Cohort", t: int) -> "Cohort":
        return cohort

    def cohort(self, t: int) -> Cohort:
        c = self.cohort_size
        rng = self.rng(t)
        picked: list = []
        seen = set()
        budget = c * self.max_draw_factor
        draws = 0
        while len(picked) < c and draws < budget:
            cand = int(rng.integers(self.spec.size))
            draws += 1
            if cand in seen:
                continue
            seen.add(cand)
            if not self._accept(rng, cand, t):
                continue
            picked.append(cand)
        ids = np.zeros(c, dtype=np.int64)
        active = np.zeros(c, dtype=bool)
        if picked:
            ids[: len(picked)] = picked
            active[: len(picked)] = True
        cohort = Cohort(ids=ids, active=active, weights=_uniform_weights(active))
        return self._finalize(rng, cohort, t)


def _uniform_weights(active: np.ndarray) -> np.ndarray:
    w = active.astype(np.float64)
    n = w.sum()
    return w / n if n > 0 else w


@dataclasses.dataclass(frozen=True)
class UniformSampler(CohortSampler):
    """Uniform without replacement over the whole population."""


@dataclasses.dataclass(frozen=True)
class SizeWeightedSampler(CohortSampler):
    """Importance sampling ∝ local dataset size (clipped at spec.size_cap):
    accept a uniform candidate with probability size/size_cap. Aggregation
    stays uniform — sampling ∝ size with uniform weights is the unbiased
    importance-sampled form of size-weighted FedAvg."""

    def _accept(self, rng, cid, t):
        size = float(self.spec.data_size(np.asarray([cid]))[0])
        return rng.random() < min(size / self.spec.size_cap, 1.0)


@dataclasses.dataclass(frozen=True)
class AvailabilitySampler(CohortSampler):
    """Uniform over the clients whose availability window is open at
    round t; a sparse window can leave slots unfilled (active=False)."""

    def _accept(self, rng, cid, t):
        return bool(self.spec.available(np.asarray([cid]), t)[0])


@dataclasses.dataclass(frozen=True)
class DeadlineSampler(CohortSampler):
    """Straggler-deadline: sample uniformly, then deactivate slots whose
    per-round latency draw exceeds ``deadline`` — they were invited but
    miss the round (counted in participation/goodput stats)."""

    deadline: float = 2.0

    def _finalize(self, rng, cohort, t):
        lat = self.spec.latency(cohort.ids, t)
        active = cohort.active & (lat <= self.deadline)
        return Cohort(ids=cohort.ids, active=active,
                      weights=_uniform_weights(active))


def make_sampler(kind: str, spec: FleetSpec, cohort_size: int, *, seed: int = 0) -> CohortSampler:
    """Registry: ``uniform``, ``weighted``, ``availability``,
    ``deadline[:cutoff]``."""
    parts = kind.split(":")
    name = parts[0]
    if name == "uniform":
        return UniformSampler(spec, cohort_size, seed=seed)
    if name == "weighted":
        return SizeWeightedSampler(spec, cohort_size, seed=seed)
    if name == "availability":
        return AvailabilitySampler(spec, cohort_size, seed=seed)
    if name == "deadline":
        cut = float(parts[1]) if len(parts) > 1 else 2.0
        return DeadlineSampler(spec, cohort_size, seed=seed, deadline=cut)
    raise ValueError(f"unknown sampler kind: {kind!r}")
