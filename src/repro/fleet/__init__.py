"""repro.fleet — federated client-zoo simulator (DESIGN.md §9).

Simulates thousands-to-millions of heterogeneous federated clients
without instantiating them: every per-client attribute (data tier, local
dataset size, latency, availability phase, fault rate, problem data) is a
pure hash of the client id, so only each round's sampled cohort is ever
materialized.

* :mod:`~repro.fleet.population` — declarative client-mix specs
  (:class:`FleetSpec`, ``make_fleet`` registry) and the hash-derived
  per-client L1 problem (:class:`FleetL1Problem`);
* :mod:`~repro.fleet.sampler` — participation: jittable slot
  :class:`ParticipationPlan` masks (the trainer/core hook) and host-side
  :class:`CohortSampler` schedulers over client ids;
* :mod:`~repro.fleet.cohort` — ``fleet_run``, the cohort-bounded
  MARINA-P / EF21-P host loop with join-sync bit accounting.
"""
from .cohort import (  # noqa: F401
    ParticipationStats,
    fleet_run,
    make_ef21p_cohort_step,
    make_marina_cohort_step,
)
from .population import (  # noqa: F401
    AvailabilityTrace,
    ComputeProfile,
    DataTier,
    FleetL1Problem,
    FleetSpec,
    make_fleet,
)
from .sampler import (  # noqa: F401
    PARTICIPATION_FOLD,
    AvailabilitySampler,
    AvailabilityWindowPlan,
    BernoulliStragglerPlan,
    Cohort,
    CohortSampler,
    CyclingMaskPlan,
    DeadlineSampler,
    FullParticipation,
    ParticipationPlan,
    SizeWeightedSampler,
    UniformSampler,
    make_sampler,
    plan_from_legacy,
)
