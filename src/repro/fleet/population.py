"""Declarative client populations (DESIGN.md §9.1).

A :class:`FleetSpec` describes thousands-to-millions of federated clients
*without instantiating them*: every per-client attribute — data tier,
local dataset size, per-round latency, availability phase, fault
severity — is a pure function of ``(spec.seed, client_id)`` evaluated
through a counter-based hash (splitmix64). Asking for the attributes of a
64-client cohort therefore costs O(64) regardless of ``spec.size``; no
population-sized array is ever built.

Components:

* :class:`DataTier` — a data-heterogeneity stratum (Algorithm 3's noise
  scale ``nu_i = 1 + s xi_i`` becomes per-tier ``s``), with a lognormal
  local-dataset-size distribution for size-weighted sampling;
* :class:`ComputeProfile` — lognormal per-(client, round) latency, the
  input to straggler-deadline sampling;
* :class:`AvailabilityTrace` — a diurnal duty-cycle window with a
  per-client phase, so only a deterministic slice of the population is
  eligible each round;
* per-client fault severity that plugs into the existing
  :class:`repro.transport.FaultSpec` (``fault_spec_for``).

:class:`FleetL1Problem` extends the paper's L1 workload (Algorithm 3) to
a fleet: client ``i``'s matrix ``A_i = nu_i * tridiag + shift*I`` is
materialized on demand for a cohort of ids — cohort size, not population
size, bounds memory. The eigenvalue shift uses the *analytic* population
mean (``E[nu] = 1`` exactly), so the problem is well-posed without ever
touching all ``N`` matrices.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Stateless per-client hashing (splitmix64)
# ---------------------------------------------------------------------------

_U64 = np.uint64

# attribute salts: one stream per attribute family
SALT_TIER = 0x7469
SALT_SIZE = 0x737A
SALT_NU = 0x6E75
SALT_PHASE = 0x7068
SALT_LATENCY = 0x6C61
SALT_FAULT = 0x6661
SALT_X0 = 0x7830
SALT_EVAL = 0x6576


def _mix(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64 arrays."""
    with np.errstate(over="ignore"):
        z = (x + _U64(0x9E3779B97F4A7C15)).astype(_U64)
        z = (z ^ (z >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> _U64(27))) * _U64(0x94D049BB133111EB)
        return z ^ (z >> _U64(31))


def hash_u64(ids, seed: int, salt: int, extra: int = 0) -> np.ndarray:
    """Deterministic 64-bit hash of (seed, salt, extra, id) per element."""
    ids = np.asarray(ids, dtype=np.uint64)
    h = _mix(_mix(np.asarray(seed, dtype=_U64)) ^ _mix(np.asarray(salt, dtype=_U64)))
    if extra:
        h = _mix(h ^ _mix(np.asarray(extra, dtype=_U64)))
    return _mix(ids ^ h)


def hash_uniform(ids, seed: int, salt: int, extra: int = 0) -> np.ndarray:
    """Uniform floats in (0, 1), one per id, deterministic."""
    h = hash_u64(ids, seed, salt, extra)
    return ((h >> _U64(11)).astype(np.float64) + 0.5) * (2.0 ** -53)


def hash_normal(ids, seed: int, salt: int, extra: int = 0) -> np.ndarray:
    """Standard normals via Box–Muller on two hashed uniform streams."""
    u1 = hash_uniform(ids, seed, salt, extra)
    u2 = hash_uniform(ids, seed, salt + 0x5A5A, extra)
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


# ---------------------------------------------------------------------------
# Spec components
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DataTier:
    """One data-heterogeneity stratum of the population.

    ``weight`` is the population fraction (normalized across tiers);
    ``noise_scale`` is Algorithm 3's per-worker scale ``s`` in
    ``nu_i = 1 + s xi_i``; local dataset sizes are lognormal with median
    ``size_median`` and log-sigma ``size_sigma`` (size-weighted sampling).
    """

    name: str
    weight: float = 1.0
    noise_scale: float = 1.0
    size_median: float = 1.0
    size_sigma: float = 0.0


@dataclasses.dataclass(frozen=True)
class ComputeProfile:
    """Lognormal per-(client, round) latency: median * exp(sigma * N(0,1))."""

    latency_median: float = 1.0
    latency_sigma: float = 0.0


@dataclasses.dataclass(frozen=True)
class AvailabilityTrace:
    """Diurnal duty-cycle: client ``i`` is available in rounds ``t`` with
    ``(t + phase_i) mod period < ceil(duty * period)``; ``phase_i`` is
    hashed per client. ``duty=1`` means always available."""

    period: int = 1
    duty: float = 1.0

    @property
    def open_ticks(self) -> int:
        return max(1, int(np.ceil(self.duty * self.period)))


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """A population of ``size`` clients, described declaratively.

    ``fault_rate`` is the population-mean per-frame drop probability; each
    client gets an Exp(1)-distributed severity multiplier (hashed), capped
    at 0.9, so a few clients are much flakier than the mean — the spec
    plugs into :class:`repro.transport.FaultSpec` via
    :meth:`fault_spec_for`.
    """

    size: int
    tiers: Tuple[DataTier, ...] = (DataTier("default"),)
    compute: ComputeProfile = ComputeProfile()
    availability: AvailabilityTrace = AvailabilityTrace()
    fault_rate: float = 0.0
    seed: int = 0

    def __post_init__(self):
        assert self.size >= 1 and self.tiers, (self.size, self.tiers)

    # -- per-client attributes (all vectorized over an ids array) ----------

    @functools.cached_property
    def _tier_cum(self) -> np.ndarray:
        w = np.asarray([t.weight for t in self.tiers], dtype=np.float64)
        return np.cumsum(w / w.sum())

    def tier_index(self, ids) -> np.ndarray:
        u = hash_uniform(ids, self.seed, SALT_TIER)
        return np.minimum(
            np.searchsorted(self._tier_cum, u, side="right"), len(self.tiers) - 1
        )

    def _tier_field(self, ids, field: str) -> np.ndarray:
        vals = np.asarray([getattr(t, field) for t in self.tiers], dtype=np.float64)
        return vals[self.tier_index(ids)]

    def noise_scale(self, ids) -> np.ndarray:
        return self._tier_field(ids, "noise_scale")

    def data_size(self, ids) -> np.ndarray:
        """Relative local dataset size (lognormal per tier), > 0."""
        med = self._tier_field(ids, "size_median")
        sig = self._tier_field(ids, "size_sigma")
        return med * np.exp(sig * hash_normal(ids, self.seed, SALT_SIZE))

    @property
    def size_cap(self) -> float:
        """Clip bound for size-weighted acceptance sampling (~99.9%-ile)."""
        return max(
            t.size_median * float(np.exp(3.1 * t.size_sigma)) for t in self.tiers
        )

    def latency(self, ids, t: int) -> np.ndarray:
        """Per-(client, round) compute+link latency draw (virtual seconds)."""
        c = self.compute
        z = hash_normal(ids, self.seed, SALT_LATENCY, extra=t + 1)
        return c.latency_median * np.exp(c.latency_sigma * z)

    def phase(self, ids) -> np.ndarray:
        period = max(1, self.availability.period)
        return (hash_u64(ids, self.seed, SALT_PHASE) % _U64(period)).astype(np.int64)

    def available(self, ids, t: int) -> np.ndarray:
        a = self.availability
        if a.duty >= 1.0 or a.period <= 1:
            return np.ones(np.asarray(ids).shape, dtype=bool)
        return ((int(t) + self.phase(ids)) % a.period) < a.open_ticks

    def drop_prob(self, ids) -> np.ndarray:
        """Per-client frame drop probability: fault_rate * Exp(1), capped."""
        if self.fault_rate <= 0:
            return np.zeros(np.asarray(ids).shape, dtype=np.float64)
        sev = -np.log(hash_uniform(ids, self.seed, SALT_FAULT))
        return np.minimum(self.fault_rate * sev, 0.9)

    def fault_spec_for(self, client_id: int, *, round_salt: int = 0):
        """A :class:`repro.transport.FaultSpec` for one client's link,
        seeded deterministically from (spec.seed, client_id, round)."""
        from repro.transport import FaultSpec

        seed = int(hash_u64(np.asarray([client_id]), self.seed, SALT_FAULT,
                            extra=round_salt + 1)[0] % _U64(2**31))
        return FaultSpec(drop=float(self.drop_prob(np.asarray([client_id]))[0]),
                         seed=seed)


# ---------------------------------------------------------------------------
# Named client mixes (the scenario matrix's client-mix axis)
# ---------------------------------------------------------------------------


def make_fleet(mix: str, size: int, *, seed: int = 0) -> FleetSpec:
    """Registry of named client mixes.

    * ``uniform`` — one homogeneous tier, always available, clean links;
    * ``two_tier`` — 70% low-noise "edge" + 30% high-noise "dc" data, with
      a 4x dataset-size spread between them;
    * ``two_tier_diurnal`` — two_tier plus a 50%-duty diurnal availability
      window and lognormal latency spread;
    * ``flaky_mobile`` — two_tier_diurnal plus a 5%-mean per-frame drop
      rate with Exp(1) per-client severity.
    """
    if mix == "uniform":
        return FleetSpec(size=size, tiers=(DataTier("all", 1.0, 1.0),), seed=seed)
    two_tier = (
        DataTier("edge", weight=0.7, noise_scale=0.3, size_median=1.0, size_sigma=0.25),
        DataTier("dc", weight=0.3, noise_scale=3.0, size_median=4.0, size_sigma=0.25),
    )
    if mix == "two_tier":
        return FleetSpec(size=size, tiers=two_tier, seed=seed)
    if mix == "two_tier_diurnal":
        return FleetSpec(
            size=size, tiers=two_tier,
            compute=ComputeProfile(latency_median=1.0, latency_sigma=0.6),
            availability=AvailabilityTrace(period=24, duty=0.5), seed=seed,
        )
    if mix == "flaky_mobile":
        return FleetSpec(
            size=size, tiers=two_tier,
            compute=ComputeProfile(latency_median=1.0, latency_sigma=0.6),
            availability=AvailabilityTrace(period=24, duty=0.5),
            fault_rate=0.05, seed=seed,
        )
    raise ValueError(f"unknown client mix: {mix!r}")


# ---------------------------------------------------------------------------
# Fleet-scale L1 workload
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetL1Problem:
    """The paper's L1 finite-sum over a declarative client population.

    ``A_i = nu_i * tridiag(d) + shift * I`` with ``nu_i = 1 + s_tier(i) *
    xi_i`` hashed per client (Algorithm 3 per tier). The mean-eigenvalue
    shift uses the analytic population mean ``E[A] = tridiag(d)`` (because
    ``E[nu] = 1`` exactly), so the construction never touches more than a
    cohort of matrices at once.
    """

    spec: FleetSpec
    d: int
    mu: float = 1e-6

    @functools.cached_property
    def _base(self) -> np.ndarray:
        m = 2.0 * np.eye(self.d) - np.eye(self.d, k=1) - np.eye(self.d, k=-1)
        return m / 4.0

    @functools.cached_property
    def _base_eigs(self) -> np.ndarray:
        # tridiagonal Toeplitz eigenvalues: (2 - 2 cos(pi j / (d+1))) / 4
        j = np.arange(1, self.d + 1)
        return (2.0 - 2.0 * np.cos(np.pi * j / (self.d + 1))) / 4.0

    @functools.cached_property
    def shift(self) -> float:
        return self.mu - float(self._base_eigs.min())

    @functools.cached_property
    def x0(self) -> np.ndarray:
        rng = np.random.default_rng(
            int(hash_u64(np.asarray([0]), self.spec.seed, SALT_X0)[0])
        )
        return rng.standard_normal(self.d)

    @property
    def f_star(self) -> float:
        return 0.0  # f_i >= 0 and f_i(0) = 0 for every client

    @property
    def R0_sq(self) -> float:
        return float(np.sum(self.x0**2))

    def nu(self, ids) -> np.ndarray:
        """Per-client Algorithm-3 scale: nu_i = 1 + s_tier(i) * xi_i."""
        return 1.0 + self.spec.noise_scale(ids) * hash_normal(
            ids, self.spec.seed, SALT_NU
        )

    def materialize(self, ids) -> np.ndarray:
        """Cohort matrices [len(ids), d, d] — O(cohort * d^2) memory."""
        nu = self.nu(ids)
        return nu[:, None, None] * self._base[None] + self.shift * np.eye(self.d)[None]

    def client_L0(self, ids) -> np.ndarray:
        """Spectral norms ||A_i||_2 from the analytic eigenvalue formula:
        eig(nu*B + shift*I) = nu*eig(B) + shift — no per-client eigensolve."""
        nu = self.nu(ids)
        eigs = nu[:, None] * self._base_eigs[None, :] + self.shift
        return np.abs(eigs).max(axis=-1)

    def lipschitz_estimates(self, n_probe: int = 256) -> Tuple[float, float]:
        """(L0_bar, L0_tilde) estimated on a hashed probe cohort."""
        n = min(n_probe, self.spec.size)
        ids = np.unique(
            (hash_u64(np.arange(n), self.spec.seed, SALT_EVAL, extra=7)
             % _U64(self.spec.size)).astype(np.int64)
        )
        L = self.client_L0(ids)
        return float(L.mean()), float(np.sqrt((L**2).mean()))

    def eval_cohort(self, m: int = 64) -> np.ndarray:
        """A fixed hashed evaluation cohort (population-objective probe)."""
        m = min(m, self.spec.size)
        ids = (hash_u64(np.arange(m), self.spec.seed, SALT_EVAL)
               % _U64(self.spec.size)).astype(np.int64)
        return ids
