"""Cohort-bounded federated rounds (DESIGN.md §9.3).

``fleet_run`` drives MARINA-P or EF21-P over a declarative client
population: each round a :class:`~repro.fleet.sampler.CohortSampler`
draws a cohort of client ids, the cohort's problem data is materialized
on demand (:meth:`FleetL1Problem.materialize`), and one jitted step vmaps
the subgradient/compressor path over the cohort — so **cohort size, not
population size, bounds memory**.

Cross-device clients are stateless between the rounds they attend, which
changes the downlink state machine vs the fixed-worker-list runs in
``repro.core``:

* a slot whose client is **fresh** (new to the cohort, or *dirty* from a
  failed delivery last time it attended) first receives the current
  server iterate dense — a *join sync*, charged dense bits;
* a **persistent** slot (same client as last round, last message
  delivered) holds valid state and receives only the compressed round
  message (MARINA-P: ``Q_i(x^{t+1}-x^t)`` or the Bernoulli full sync;
  EF21-P: the contractive shift delta);
* a slot whose round message is **dropped** (per-client
  :class:`~repro.transport.FaultSpec` drawn from the population's fault
  rate, evaluated through the transport fault injector) keeps its stale
  state and is marked dirty — its *next* attendance is a join sync. There
  is no fleet-wide rollback or forced-sync promotion: with per-round
  membership churn, the join sync already is the repair primitive
  (contrast DESIGN.md §8.4's fixed-fleet two-phase commit).

The global objective is estimated on a fixed hashed evaluation cohort
(``FleetL1Problem.eval_cohort``) — evaluating the true population
objective would require materializing every client.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm_model import CommModel
from repro.core.compressors import ContractiveCompressor, TopK
from repro.core.marina_p import make_broadcast
from repro.core.problems import paper_sign
from repro.core.stepsizes import Stepsize
from repro.obs.trace import maybe_attr, maybe_span

from .population import FleetL1Problem
from .sampler import CohortSampler


def _cohort_oracles(A, points):
    """(f_i, df_i) at per-slot points: [c,d,d] x [c,d] -> ([c], [c,d])."""
    y = jnp.einsum("cij,cj->ci", A, points)
    f = jnp.sum(jnp.abs(y), axis=-1)
    g = jnp.einsum("cij,ci->cj", A, paper_sign(y))
    return f, g


def _aggregate(weights, f_all, g_all):
    """Weighted cohort aggregation; weights are zero-sum on empty rounds,
    so an empty cohort yields g = 0 and the server iterate holds still."""
    g = jnp.tensordot(weights, g_all, axes=1)
    aux = {
        "f_w": jnp.sum(weights * f_all),
        "g_norm_sq": jnp.sum(g**2),
        "g_sq_mean": jnp.sum(weights * jnp.sum(g_all**2, axis=-1)),
    }
    return g, aux


def make_marina_cohort_step(cohort_size: int, mode: str, k: int, p: float,
                            stepsize: Stepsize):
    """Jittable MARINA-P cohort round over [c] slots.

    Inputs: server x [d], slot shifts W [c,d], cohort matrices A [c,d,d],
    active/weights/fresh [c], key, round t. Fresh slots start from x (the
    join sync already delivered it); the broadcast addresses every active
    slot. Returns (x_new, W_new, w_start, metrics) — w_start is kept so
    the host can roll back slots whose delivery failed.
    """
    bcast, _ = make_broadcast(mode, cohort_size, k)

    def step(x, W, A, active, weights, fresh, key, t):
        k_bern, k_comp = jax.random.split(key)
        w_start = jnp.where(fresh[:, None], x[None, :], W)
        f_all, g_all = _cohort_oracles(A, w_start)
        g, aux = _aggregate(weights, f_all, g_all)
        gamma = stepsize(t, aux)
        x_new = x - gamma * g
        coin = jax.random.bernoulli(k_bern, p)
        Q = bcast(k_comp, x_new - x)  # [c, d]
        W_round = jnp.where(coin, jnp.broadcast_to(x_new, W.shape), w_start + Q)
        W_new = jnp.where(active[:, None], W_round, W)
        metrics = {
            "f_w": aux["f_w"],
            "gamma": gamma,
            "full_sync": coin.astype(jnp.float32),
            "q_nnz": jnp.sum(Q != 0, axis=-1).astype(jnp.float32),
            "x_new": x_new,
            "Q": Q,
        }
        return x_new, W_new, w_start, metrics

    return step


def make_ef21p_cohort_step(comp: ContractiveCompressor, stepsize: Stepsize):
    """Jittable EF21-P cohort round: the shift w is a single server-side
    vector; fresh slots received it dense at round start, so the whole
    active cohort computes at w and the compressed delta keeps the
    persistent slots synchronized."""

    def step(x, w, A, active, weights, key, t):
        points = jnp.broadcast_to(w, A.shape[:1] + w.shape)
        f_all, g_all = _cohort_oracles(A, points)
        g, aux = _aggregate(weights, f_all, g_all)
        gamma = stepsize(t, aux)
        x_new = x - gamma * g
        delta = comp(key, x_new - w)
        w_new = w + delta
        metrics = {
            "f_w": aux["f_w"],
            "gamma": gamma,
            "delta_nnz": jnp.sum(delta != 0).astype(jnp.float32),
            "delta": delta,
        }
        return x_new, w_new, metrics

    return step


@dataclasses.dataclass
class ParticipationStats:
    """Fleet-level participation/goodput counters for one run."""

    rounds: int = 0
    participant_rounds: int = 0
    fresh_rounds: int = 0
    messages_sent: int = 0
    messages_delivered: int = 0
    unique_clients: int = 0
    mean_fill: float = 0.0

    @property
    def fresh_frac(self) -> float:
        return self.fresh_rounds / max(self.participant_rounds, 1)

    @property
    def goodput(self) -> float:
        return self.messages_delivered / max(self.messages_sent, 1)

    def as_metrics(self, prefix: str = "fleet") -> Dict[str, float]:
        return {
            f"{prefix}/rounds": float(self.rounds),
            f"{prefix}/participant_rounds": float(self.participant_rounds),
            f"{prefix}/unique_clients": float(self.unique_clients),
            f"{prefix}/mean_fill": self.mean_fill,
            f"{prefix}/fresh_frac": self.fresh_frac,
            f"{prefix}/goodput": self.goodput,
        }


def fleet_run(
    problem: FleetL1Problem,
    sampler: CohortSampler,
    stepsize: Stepsize,
    *,
    algorithm: str = "marina_p",
    mode: str = "perm",
    k: Optional[int] = None,
    p: Optional[float] = None,
    comp: Optional[ContractiveCompressor] = None,
    T: int = 200,
    target: Optional[float] = None,
    seed: int = 0,
    record_every: int = 1,
    measure_wire: bool = False,
    wire_mag: str = "fp32",
    device_encode: Optional[bool] = None,
    eval_clients: int = 64,
    tracker=None,
):
    """Host loop for one (algorithm × sampler × population) scenario.

    Downlink bits follow the paper's 64-bit CommModel: every fresh active
    slot is charged one dense join sync; MARINA-P sync rounds charge dense
    per active slot, otherwise each slot's actual message nnz; EF21-P
    charges the delta nnz per persistent slot. Uplink stays one exact
    dense message per participant per round. ``measure_wire=True``
    additionally serializes every per-slot message with the repro.wire
    codecs (``hist["wire_bits"]``, DESIGN.md §3.5); the round's cohort
    encodes are batched before the per-slot delivery loop — one vmapped
    device pass over the active Q rows when ``device_encode`` selects the
    fused kernels (kernels/encode.py; None defers to
    ``REPRO_DEVICE_ENCODE``/backend auto-detect), one host pass otherwise.

    ``target`` (an f-value on the evaluation cohort) sets
    ``hist["rounds_to_target"]`` — the first recorded round at or below
    it, or T when never reached (keeps BENCH gates NaN-free).
    """
    assert algorithm in ("marina_p", "ef21p"), algorithm
    spec = problem.spec
    c, d = sampler.cohort_size, problem.d
    k = k if k is not None else max(1, d // c)
    p = p if p is not None else k / d
    if comp is None:
        comp = TopK(k=k)
    cm = CommModel(d=d)
    use_dev = False
    if measure_wire:
        from repro import wire
        from repro.kernels import encode as kenc

        use_dev = kenc.device_encode_enabled(device_encode)

        def enc_dense(v):
            if use_dev:
                return kenc.dense_encode(v, mag=wire_mag)
            return wire.encode_dense(np.asarray(v), mag=wire_mag)

        def enc_sparse(v):
            if use_dev:
                return kenc.sparse_encode(v, mag=wire_mag)
            return wire.encode_sparse(np.asarray(v), mag=wire_mag)

        def enc_rows(Q):
            if use_dev:
                return kenc.encode_rows(Q, mag=wire_mag)
            Qh = np.asarray(Q)
            return [wire.encode_sparse(Qh[i], mag=wire_mag)
                    for i in range(Qh.shape[0])]

    # -- evaluation cohort (fixed, hashed) --------------------------------
    eval_ids = problem.eval_cohort(eval_clients)
    A_eval = jnp.asarray(problem.materialize(eval_ids), jnp.float32)

    @jax.jit
    def f_eval(x):
        return jnp.mean(jnp.sum(jnp.abs(jnp.einsum("cij,j->ci", A_eval, x)), axis=-1))

    if algorithm == "marina_p":
        step = jax.jit(make_marina_cohort_step(c, mode, k, p, stepsize))
    else:
        step = jax.jit(make_ef21p_cohort_step(comp, stepsize))

    x = jnp.asarray(problem.x0, jnp.float32)
    W = jnp.broadcast_to(x, (c, d))  # marina_p slot shifts
    w = x                            # ef21p server shift
    key = jax.random.PRNGKey(seed)

    prev_ids = np.full(c, -1, dtype=np.int64)
    dirty: set = set()
    clients_seen: set = set()
    stats = ParticipationStats()
    s2w_bits = 0.0
    w2s_bits = 0.0
    join_bits = 0.0
    wire_bits = 0.0
    rounds_to_target = None
    hist = {"t": [], "f_x": [], "f_w": [], "gamma": [], "participants": [],
            "fresh": [], "delivered": [], "s2w_bits": [], "w2s_bits": []}
    if measure_wire:
        hist["wire_bits"] = []

    for t in range(T):
        co = sampler.cohort(t)
        fresh_np = co.active & (
            (co.ids != prev_ids) | np.isin(co.ids, np.asarray(sorted(dirty), dtype=np.int64))
        )
        A = jnp.asarray(problem.materialize(co.ids), jnp.float32)
        active = jnp.asarray(co.active)
        weights = jnp.asarray(co.weights, jnp.float32)
        key, sub = jax.random.split(key)

        with maybe_span(tracker, "round", round=t,
                        alg=f"fleet/{algorithm}") as rsp:
            with maybe_span(tracker, "subgrad",
                            fused="subgrad+stepsize+compress"):
                if algorithm == "marina_p":
                    x, W, w_start, m = step(x, W, A, active, weights,
                                            jnp.asarray(fresh_np), sub, t)
                    coin = float(m["full_sync"]) > 0
                    q_nnz = np.asarray(m["q_nnz"])
                else:
                    x, w, m = step(x, w, A, active, weights, sub, t)
                    coin = False
                    delta_nnz = float(m["delta_nnz"])
            with maybe_span(tracker, "stepsize") as ssp:
                gamma = float(m["gamma"])
                maybe_attr(ssp, gamma=gamma)
            maybe_attr(rsp, full_sync=coin, gamma=gamma)

            # -- per-slot delivery through the transport failure model -----
            n_active = co.n_active
            delivered = co.active.copy()
            payloads = [None] * c
            with maybe_span(tracker, "broadcast", full_sync=coin) as bsp:
                if measure_wire or spec.fault_rate > 0:
                    active_idx = np.nonzero(co.active)[0]
                    if measure_wire and active_idx.size:
                        # batch the round's cohort encodes before the
                        # delivery loop: the per-slot Q rows go through one
                        # vmapped device pass (or one host sweep), the
                        # shared sync / join payloads encode exactly once
                        with maybe_span(tracker, "encode", device=use_dev):
                            if algorithm == "marina_p":
                                if coin:
                                    shared = enc_dense(m["x_new"])
                                    for i in active_idx:
                                        payloads[i] = shared
                                else:
                                    rows = enc_rows(m["Q"][active_idx])
                                    for i, buf in zip(active_idx, rows):
                                        payloads[i] = buf
                            else:
                                shared = enc_sparse(m["delta"])
                                for i in active_idx:
                                    payloads[i] = shared
                            join_payload = (
                                enc_dense(x if algorithm == "marina_p" else w)
                                if fresh_np.any() else None
                            )
                    for i in active_idx:
                        cid = int(co.ids[i])
                        with maybe_span(tracker, f"link/client{cid}",
                                        fresh=bool(fresh_np[i])) as lsp:
                            if measure_wire:
                                if fresh_np[i]:
                                    wire_bits += wire.measured_bits(join_payload)
                                wire_bits += wire.measured_bits(payloads[i])
                            if spec.fault_rate > 0:
                                from repro.transport import FaultInjector

                                fspec = spec.fault_spec_for(cid, round_salt=t)
                                if fspec.any_faults:
                                    inj = FaultInjector(fspec)
                                    buf = payloads[i] if payloads[i] is not None else b"\x00" * 16
                                    delivered[i] = len(inj.plan(buf)) > 0
                            maybe_attr(lsp, delivered=bool(delivered[i]))
                maybe_attr(bsp, delivered=int(delivered.sum()),
                           fresh=int(fresh_np.sum()),
                           resync_next=not bool(delivered.all()))

            # slots whose round message was dropped keep their pre-round
            # state and resync (join dense) at their next attendance
            if algorithm == "marina_p" and not bool(delivered.all()):
                W = jnp.where(jnp.asarray(delivered)[:, None], W, w_start)
            for i in np.nonzero(co.active)[0]:
                cid = int(co.ids[i])
                if delivered[i]:
                    dirty.discard(cid)
                else:
                    dirty.add(cid)
            prev_ids = np.where(co.active, co.ids, -1)

        # -- bit accounting (paper 64-bit model) ----------------------------
        n_fresh = int(fresh_np.sum())
        join_bits += cm.dense_bits() * n_fresh
        round_s2w = cm.dense_bits() * n_fresh
        if algorithm == "marina_p":
            if coin:
                round_s2w += cm.dense_bits() * n_active
            else:
                round_s2w += float(sum(cm.sparse_bits(float(q_nnz[i]))
                                       for i in np.nonzero(co.active)[0]))
        else:
            n_persistent = n_active - n_fresh
            round_s2w += cm.sparse_bits(delta_nnz) * n_persistent
        s2w_bits += round_s2w
        w2s_bits += cm.dense_bits() * n_active

        # -- stats / recording ---------------------------------------------
        stats.rounds += 1
        stats.participant_rounds += n_active
        stats.fresh_rounds += n_fresh
        stats.messages_sent += n_active
        stats.messages_delivered += int(delivered.sum())
        stats.mean_fill += (co.fill - stats.mean_fill) / stats.rounds
        clients_seen.update(int(i) for i in co.ids[co.active])

        fx = float(f_eval(x))
        if target is not None and rounds_to_target is None and fx <= target:
            rounds_to_target = t
        if t % record_every == 0 or t == T - 1:
            hist["t"].append(t)
            hist["f_x"].append(fx)
            hist["f_w"].append(float(m["f_w"]))
            hist["gamma"].append(gamma)
            hist["participants"].append(n_active)
            hist["fresh"].append(n_fresh)
            hist["delivered"].append(int(delivered.sum()))
            hist["s2w_bits"].append(s2w_bits)
            hist["w2s_bits"].append(w2s_bits)
            if measure_wire:
                hist["wire_bits"].append(wire_bits)
            if tracker is not None:
                pre = f"fleet/{algorithm}"
                tracker.log({f"{pre}/f_x": fx, f"{pre}/gamma": hist["gamma"][-1],
                             f"{pre}/participants": n_active,
                             f"{pre}/s2w_bits": s2w_bits}, step=t)

    stats.unique_clients = len(clients_seen)
    hist["final_x"] = x
    hist["s2w_bits_total"] = s2w_bits
    hist["w2s_bits_total"] = w2s_bits
    hist["join_bits_total"] = join_bits
    hist["bits_per_participant_round"] = s2w_bits / max(stats.participant_rounds, 1)
    if measure_wire:
        hist["wire_bits_total"] = wire_bits
    hist["participation"] = stats
    if target is not None:
        hist["rounds_to_target"] = rounds_to_target if rounds_to_target is not None else T
    if tracker is not None:
        tracker.log(stats.as_metrics(f"fleet/{algorithm}"), step=T)
    return hist
