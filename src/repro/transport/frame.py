"""Length-prefixed transport frames with CRC32C trailers (DESIGN.md §8.1).

A frame wraps one wire message (repro.wire buffer) or a control payload:

    [u16 magic = 0x4652 ("FR")] [u8 version] [u8 ftype]
    [u32 seq] [u32 length]                      <- 12-byte header
    [payload: length bytes]
    [u32 crc32c over header + payload]          <- 4-byte trailer

All integers little-endian. ``seq`` is a per-link monotonic counter for
DATA/SYNC frames (control frames carry the seq they refer to). The CRC is
CRC32C (Castagnoli, reflected poly 0x82F63B78) over everything before the
trailer, so a single flipped bit anywhere in the frame is detected.

Decode failures reuse the repro.wire exception hierarchy — a short buffer
raises :class:`~repro.wire.TruncatedFrame`, a bad magic/version/CRC raises
:class:`~repro.wire.CorruptFrame` — so receivers classify transport- and
codec-level damage uniformly.
"""
from __future__ import annotations

import dataclasses
import enum
import struct

from repro.wire.spec import CorruptFrame, TruncatedFrame

FRAME_MAGIC = 0x4652  # "FR"
FRAME_VERSION = 1

_HEADER = struct.Struct("<HBBII")
HEADER_BYTES = _HEADER.size  # 12
CRC_BYTES = 4
FRAME_OVERHEAD = HEADER_BYTES + CRC_BYTES  # 16 bytes per frame
MAX_PAYLOAD = 1 << 30  # sanity bound: a corrupt length field cannot OOM us


class FrameType(enum.IntEnum):
    DATA = 1     # incremental payload; only valid at seq == expected
    SYNC = 2     # self-contained payload; repairs any sequence gap
    ACK = 3      # cumulative: "I have delivered everything below seq"
    NAK = 4      # "retransmit from seq" (corrupt frame or gap detected)
    RESYNC = 5   # "I cannot be repaired by replay; promote to a SYNC"


@dataclasses.dataclass(frozen=True)
class Frame:
    ftype: FrameType
    seq: int
    payload: bytes = b""

    @property
    def is_control(self) -> bool:
        return self.ftype in (FrameType.ACK, FrameType.NAK, FrameType.RESYNC)


# -- CRC32C (Castagnoli) ------------------------------------------------------

_CRC_POLY = 0x82F63B78


def _make_tables(n: int = 8) -> tuple:
    """Slicing-by-n lookup tables (table 0 is the classic byte table)."""
    t0 = []
    for i in range(256):
        crc = i
        for _ in range(8):
            crc = (crc >> 1) ^ (_CRC_POLY if crc & 1 else 0)
        t0.append(crc)
    tables = [tuple(t0)]
    for k in range(1, n):
        prev = tables[k - 1]
        tables.append(tuple(t0[v & 0xFF] ^ (v >> 8) for v in prev))
    return tuple(tables)


_T = _make_tables()


def crc32c(data: bytes, crc: int = 0) -> int:
    """CRC32C of ``data``; chainable via the ``crc`` argument.

    Pure-python slicing-by-8 — no hardware CRC dependency; tens of MB/s,
    plenty for frame trailers (bulk payload speed lives in the codecs).
    """
    c = ~crc & 0xFFFFFFFF
    t0, t1, t2, t3, t4, t5, t6, t7 = _T
    mv = memoryview(data)
    n8 = len(mv) - (len(mv) % 8)
    for i in range(0, n8, 8):
        c ^= int.from_bytes(mv[i : i + 4], "little")
        hi = int.from_bytes(mv[i + 4 : i + 8], "little")
        c = (
            t7[c & 0xFF] ^ t6[(c >> 8) & 0xFF] ^ t5[(c >> 16) & 0xFF] ^ t4[c >> 24]
            ^ t3[hi & 0xFF] ^ t2[(hi >> 8) & 0xFF] ^ t1[(hi >> 16) & 0xFF] ^ t0[hi >> 24]
        )
    for b in mv[n8:]:
        c = t0[(c ^ b) & 0xFF] ^ (c >> 8)
    return ~c & 0xFFFFFFFF


# -- encode / decode ----------------------------------------------------------


def encode_frame(ftype: FrameType, seq: int, payload: bytes = b"") -> bytes:
    head = _HEADER.pack(FRAME_MAGIC, FRAME_VERSION, int(ftype), seq & 0xFFFFFFFF,
                        len(payload))
    body = head + payload
    return body + struct.pack("<I", crc32c(body))


def is_frame(buf: bytes) -> bool:
    """True if ``buf`` starts with the transport frame magic (cheap peek —
    lets endpoints accept both framed and bare wire messages)."""
    return len(buf) >= 2 and struct.unpack_from("<H", buf, 0)[0] == FRAME_MAGIC


def decode_frame(buf: bytes, offset: int = 0) -> tuple[Frame, int]:
    """Decode one frame at ``offset``; returns (frame, next_offset).

    Raises :class:`TruncatedFrame` when the buffer ends early and
    :class:`CorruptFrame` on magic/version/type/length/CRC damage.
    """
    if len(buf) < offset + HEADER_BYTES:
        raise TruncatedFrame("truncated transport frame (no header)")
    magic, version, ftype, seq, length = _HEADER.unpack_from(buf, offset)
    if magic != FRAME_MAGIC:
        raise CorruptFrame(f"bad frame magic {magic:#x}")
    if version != FRAME_VERSION:
        raise CorruptFrame(f"unsupported frame version {version}")
    try:
        ftype = FrameType(ftype)
    except ValueError as e:
        raise CorruptFrame(f"unknown frame type {ftype}") from e
    if length > MAX_PAYLOAD:
        raise CorruptFrame(f"frame length {length} exceeds bound")
    end = offset + HEADER_BYTES + length + CRC_BYTES
    if len(buf) < end:
        raise TruncatedFrame(
            f"truncated transport frame ({len(buf) - offset} of {end - offset} bytes)"
        )
    body = buf[offset : end - CRC_BYTES]
    (want,) = struct.unpack_from("<I", buf, end - CRC_BYTES)
    got = crc32c(body)
    if got != want:
        raise CorruptFrame(f"frame CRC mismatch ({got:#x} != {want:#x})")
    return Frame(ftype=ftype, seq=seq, payload=bytes(buf[offset + HEADER_BYTES : end - CRC_BYTES])), end
