"""Channel abstraction: in-process loopback + fault-injecting wrapper.

A channel is a unidirectional, unreliable byte-buffer pipe with virtual
time: ``send`` enqueues a buffer, ``poll`` advances the clock one *tick*
and returns everything whose delivery time has arrived. Ticks are the
latency unit of the whole transport layer — retry timeouts, straggler
delays and reorder windows are all counted in ticks, so tests and
benchmarks are deterministic and never sleep.

:class:`LoopbackChannel` delivers in order with zero latency;
:class:`FaultyChannel` wraps any channel and pushes each send through a
seeded :class:`~repro.transport.faults.FaultInjector` (drops, bit-flips,
truncation, reordering, duplication, straggler latency). A socket-backed
channel can implement the same protocol later without touching the
framing or reliability layers (ROADMAP: replica-fleet transport).
"""
from __future__ import annotations

import heapq
import itertools
from typing import List, Optional, Protocol

from .faults import FaultInjector, FaultSpec


class Channel(Protocol):
    def send(self, buf: bytes, *, delay: int = 0) -> None:
        """Enqueue ``buf`` for delivery ``delay`` ticks from now."""

    def poll(self) -> List[bytes]:
        """Advance one tick; return buffers whose delivery time arrived."""

    @property
    def now(self) -> int:
        """Current tick count."""


class LoopbackChannel:
    """In-process channel: a delay-aware priority queue over virtual ticks."""

    def __init__(self) -> None:
        self._tick = 0
        self._order = itertools.count()  # FIFO among equal delivery times
        self._heap: list = []

    @property
    def now(self) -> int:
        return self._tick

    def send(self, buf: bytes, *, delay: int = 0) -> None:
        heapq.heappush(self._heap, (self._tick + max(delay, 0), next(self._order), buf))

    def poll(self) -> List[bytes]:
        self._tick += 1
        out = []
        while self._heap and self._heap[0][0] <= self._tick:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def pending(self) -> int:
        return len(self._heap)


class FaultyChannel:
    """Wrap a channel with seeded fault injection on the send side."""

    def __init__(self, inner: Channel, spec: FaultSpec,
                 injector: Optional[FaultInjector] = None) -> None:
        self.inner = inner
        self.spec = spec
        self.injector = injector or FaultInjector(spec)

    @property
    def now(self) -> int:
        return self.inner.now

    @property
    def counts(self):
        """Injected-fault counters, by class."""
        return self.injector.counts

    def send(self, buf: bytes, *, delay: int = 0) -> None:
        for extra, out in self.injector.plan(buf):
            self.inner.send(out, delay=delay + extra)

    def poll(self) -> List[bytes]:
        return self.inner.poll()

    def pending(self) -> int:
        return self.inner.pending()
