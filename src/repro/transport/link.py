"""Reliable delivery over unreliable channels (DESIGN.md §8.3).

One :class:`Link` is a unidirectional reliable pipe: a sender endpoint and
a receiver endpoint joined by a data channel (server -> worker) and an ack
channel (worker -> server), either of which may be a
:class:`~repro.transport.channel.FaultyChannel`. The protocol:

* every payload is framed (CRC32C + monotonic ``seq`` — frame.py);
* the receiver delivers strictly in order, stashes bounded out-of-order
  arrivals, re-acks duplicates, and answers damage/gaps with NAK(expected)
  (cumulative ACKs carry the *next needed* seq);
* the sender keeps a bounded replay ring; NAKs inside the ring replay
  immediately, timeouts retransmit with exponential backoff, and when the
  ring can no longer repair the gap (or retries exhaust) the link flags
  ``resync_needed`` — the *application* then promotes its next message to
  a self-contained SYNC frame (MARINA-P: the Bernoulli full-broadcast
  branch; EF21-P: a dense shift re-anchor), which repairs any gap.

Latency is virtual (channel ticks), so every retry/backoff/recovery path
is deterministic under a seeded FaultSpec. :class:`Fleet` bundles one link
per worker and aggregates counters into ``transport/*`` metrics for
repro.obs trackers.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, List, Optional

from repro.wire.spec import CorruptFrame, TruncatedFrame, WireError

from .channel import Channel, FaultyChannel, LoopbackChannel
from .faults import FaultSpec
from .frame import Frame, FrameType, decode_frame, encode_frame


class TransportError(RuntimeError):
    """Base class for link-level (non-codec) transport failures."""


class DeliveryFailed(TransportError):
    """Sender exhausted its retry budget; the link needs a resync."""


class StaleDelta(TransportError):
    """A framed delta's seq is at or behind the last applied one."""


class SequenceGap(TransportError):
    """A framed DATA delta skips ahead — applying it would corrupt state."""


@dataclasses.dataclass
class LinkStats:
    """Counters for one link (aggregated fleet-wide by :class:`Fleet`)."""

    frames_sent: int = 0          # first transmissions (DATA + SYNC)
    retries: int = 0              # retransmissions (timeout or NAK replay)
    resyncs: int = 0              # times the link entered resync_needed
    forced_syncs: int = 0         # SYNC frames sent to repair the link
    delivery_failures: int = 0    # sends that exhausted the retry budget
    corrupt_detected: int = 0     # CRC/codec damage caught at the receiver
    truncated_detected: int = 0
    duplicates_dropped: int = 0
    gaps_detected: int = 0
    delivered_frames: int = 0
    payload_bytes_delivered: int = 0
    wire_bytes_sent: int = 0      # includes retransmits + frame overhead
    recovery_ticks: List[int] = dataclasses.field(default_factory=list)

    @property
    def goodput(self) -> float:
        """Delivered payload bytes / total wire bytes sent (with overhead)."""
        if self.wire_bytes_sent == 0:
            return 1.0
        return self.payload_bytes_delivered / self.wire_bytes_sent

    def merge(self, other: "LinkStats") -> None:
        for f in dataclasses.fields(self):
            if f.name == "recovery_ticks":
                self.recovery_ticks.extend(other.recovery_ticks)
            else:
                setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))

    def as_metrics(self, prefix: str = "transport") -> Dict[str, float]:
        rec = self.recovery_ticks
        return {
            f"{prefix}/frames_sent": self.frames_sent,
            f"{prefix}/retries": self.retries,
            f"{prefix}/resyncs": self.resyncs,
            f"{prefix}/forced_syncs": self.forced_syncs,
            f"{prefix}/delivery_failures": self.delivery_failures,
            f"{prefix}/corrupt_detected": self.corrupt_detected,
            f"{prefix}/truncated_detected": self.truncated_detected,
            f"{prefix}/duplicates_dropped": self.duplicates_dropped,
            f"{prefix}/gaps_detected": self.gaps_detected,
            f"{prefix}/delivered_frames": self.delivered_frames,
            f"{prefix}/goodput": self.goodput,
            f"{prefix}/recovery_ticks_mean": (sum(rec) / len(rec)) if rec else 0.0,
            f"{prefix}/recovery_ticks_max": max(rec) if rec else 0.0,
        }


class _Receiver:
    """Receiver endpoint: validate, order, deliver; answer with ACK/NAK."""

    def __init__(self, stats: LinkStats, *, window: int = 32) -> None:
        self.stats = stats
        self.window = window
        self.expected = 0
        self.delivered: collections.deque = collections.deque()
        self._stash: Dict[int, bytes] = {}
        self._last_naked = -1

    def on_frame(self, raw: bytes) -> List[bytes]:
        """Process one arrival; returns control frames for the ack channel."""
        try:
            frame, _ = decode_frame(raw)
        except TruncatedFrame:
            self.stats.truncated_detected += 1
            return self._nak()
        except CorruptFrame:
            self.stats.corrupt_detected += 1
            return self._nak()
        if frame.is_control:  # misrouted control frame: ignore
            return []
        if frame.ftype == FrameType.SYNC:
            if frame.seq < self.expected:  # stale duplicate of an old sync
                self.stats.duplicates_dropped += 1
                return [self._ack()]
            self._deliver(frame)
            self.expected = frame.seq + 1
            self._stash = {s: p for s, p in self._stash.items() if s >= self.expected}
            self._flush()
            return [self._ack()]
        # DATA
        if frame.seq < self.expected or frame.seq in self._stash:
            self.stats.duplicates_dropped += 1
            return [self._ack()]
        if frame.seq == self.expected:
            self._deliver(frame)
            self.expected += 1
            self._flush()
            self._last_naked = -1
            return [self._ack()]
        # gap: frame.seq > expected
        self.stats.gaps_detected += 1
        if frame.seq < self.expected + self.window:
            self._stash[frame.seq] = frame.payload
        return self._nak() + [self._ack()]

    def _deliver(self, frame: Frame) -> None:
        self.delivered.append(frame.payload)
        self.stats.delivered_frames += 1
        self.stats.payload_bytes_delivered += len(frame.payload)

    def _flush(self) -> None:
        while self.expected in self._stash:
            payload = self._stash.pop(self.expected)
            self.delivered.append(payload)
            self.stats.delivered_frames += 1
            self.stats.payload_bytes_delivered += len(payload)
            self.expected += 1

    def _ack(self) -> bytes:
        return encode_frame(FrameType.ACK, self.expected)

    def _nak(self) -> List[bytes]:
        if self._last_naked == self.expected:
            return []  # one NAK per missing seq; duplicates add nothing
        self._last_naked = self.expected
        return [encode_frame(FrameType.NAK, self.expected)]


class _Sender:
    """Sender endpoint: seq assignment, bounded replay ring, NAK replay."""

    def __init__(self, data: Channel, stats: LinkStats, *, replay_depth: int) -> None:
        self.data = data
        self.stats = stats
        self.replay_depth = replay_depth
        self.next_seq = 0
        self.acked_upto = 0  # every seq below this is delivered
        self.resync_needed = False
        self._replay: "collections.OrderedDict[int, bytes]" = collections.OrderedDict()

    def transmit_new(self, payload: bytes, ftype: FrameType) -> int:
        seq = self.next_seq
        self.next_seq += 1
        raw = encode_frame(ftype, seq, payload)
        self._replay[seq] = raw
        while len(self._replay) > self.replay_depth:
            self._replay.popitem(last=False)
        self.stats.frames_sent += 1
        self._put(raw)
        return seq

    def retransmit(self, seq: int) -> bool:
        raw = self._replay.get(seq)
        if raw is None:
            return False
        self.stats.retries += 1
        self._put(raw)
        return True

    def on_control(self, raw: bytes) -> None:
        try:
            frame, _ = decode_frame(raw)
        except WireError:
            return  # damaged ack/nak: the retry timer covers it
        if frame.ftype == FrameType.ACK:
            if frame.seq > self.acked_upto:
                self.acked_upto = frame.seq
                for s in [s for s in self._replay if s < frame.seq]:
                    del self._replay[s]
        elif frame.ftype == FrameType.NAK:
            # replay everything from the hole; a miss means the ring was
            # evicted and only an application-level SYNC can repair it
            missing = [s for s in range(frame.seq, self.next_seq) if s >= self.acked_upto]
            for s in missing:
                if not self.retransmit(s):
                    self._flag_resync()
                    break
        elif frame.ftype == FrameType.RESYNC:
            self._flag_resync()

    def _flag_resync(self) -> None:
        if not self.resync_needed:
            self.resync_needed = True
            self.stats.resyncs += 1

    def _put(self, raw: bytes) -> None:
        self.stats.wire_bytes_sent += len(raw)
        self.data.send(raw)


class Link:
    """In-process reliable link driving both endpoints' virtual clocks.

    ``send`` blocks (in virtual ticks, not wall time) until the payload is
    cumulatively acked or the retry budget is spent. A socket transport
    would split the two endpoints across processes and replace ``_pump``
    with its event loop; the framing and recovery logic stay as-is.
    """

    def __init__(
        self,
        *,
        fault_spec: Optional[FaultSpec] = None,
        ack_fault_spec: Optional[FaultSpec] = None,
        timeout: int = 4,
        max_retries: int = 8,
        backoff: float = 2.0,
        replay_depth: int = 32,
        window: int = 32,
        name: str = "link",
    ) -> None:
        self.name = name
        self.stats = LinkStats()
        self.tracker = None  # repro.obs Tracker: per-send link/* spans (§10)
        data: Channel = LoopbackChannel()
        if fault_spec is not None and fault_spec.any_faults:
            data = FaultyChannel(data, fault_spec)
        ack: Channel = LoopbackChannel()
        if ack_fault_spec is not None and ack_fault_spec.any_faults:
            ack = FaultyChannel(ack, ack_fault_spec)
        self.data = data
        self.ack = ack
        self.timeout = timeout
        self.max_retries = max_retries
        self.backoff = backoff
        self.sender = _Sender(data, self.stats, replay_depth=replay_depth)
        self.receiver = _Receiver(self.stats, window=window)

    @property
    def resync_needed(self) -> bool:
        return self.sender.resync_needed

    def send(self, payload: bytes, *, sync: bool = False) -> bool:
        """Deliver one payload reliably; returns False on delivery failure.

        ``sync=True`` sends a self-contained SYNC frame, which repairs any
        receiver-side gap and clears the link's resync flag on delivery.

        With a ``tracker`` attached, the whole send -> ack cycle is traced
        as a ``link/<name>`` span (retry/resync deltas and the carried
        LinkStats counters as attrs, DESIGN.md §10.2), with a zero-width
        ``link/<name>/retry`` marker span per retransmission attempt.
        """
        from repro.obs.trace import maybe_attr, maybe_span

        was_resync = self.sender.resync_needed
        r0, rs0, tick0 = self.stats.retries, self.stats.resyncs, self.data.now
        with maybe_span(
            self.tracker, f"link/{self.name}",
            ftype="SYNC" if sync else "DATA", bytes=len(payload),
        ) as sp:
            ok = self._send(payload, sync=sync)
            maybe_attr(
                sp,
                delivered=ok,
                seq=self.sender.next_seq - 1,
                retries=self.stats.retries - r0,
                resyncs=self.stats.resyncs - rs0,
                resync_needed=self.sender.resync_needed,
                repaired_resync=bool(sync and was_resync and ok),
                ticks=self.data.now - tick0,
            )
        return ok

    def _send(self, payload: bytes, *, sync: bool = False) -> bool:
        ftype = FrameType.SYNC if sync else FrameType.DATA
        if sync and self.sender.resync_needed:
            self.stats.forced_syncs += 1  # a repair, not an organic sync round
        start = self.data.now
        seq = self.sender.transmit_new(payload, ftype)
        timeout = self.timeout
        retransmits = 0
        for attempt in range(self.max_retries + 1):
            for _ in range(timeout):
                self._pump()
                if self.sender.acked_upto > seq:
                    if attempt > 0 or retransmits > 0:
                        self.stats.recovery_ticks.append(self.data.now - start)
                    if sync:
                        self.sender.resync_needed = False
                    return True
            if attempt < self.max_retries:
                if self.sender.retransmit(seq):
                    retransmits += 1
                    if self.tracker is not None:
                        with self.tracker.span(
                            f"link/{self.name}/retry", seq=seq, attempt=attempt + 1
                        ):
                            pass
                timeout = max(1, math.ceil(timeout * self.backoff))
        self.stats.delivery_failures += 1
        self.sender._flag_resync()
        return False

    def send_nowait(self, payload: bytes, *, sync: bool = False) -> int:
        """Pipelined transmit: enqueue a frame without waiting for its ack.

        Pair with :meth:`flush`. Pipelining is what exercises receiver gap
        detection and out-of-order stashing — a dropped frame is noticed
        when its successor arrives, NAKed, and repaired from the replay
        ring without stalling the pipe.
        """
        if sync and self.sender.resync_needed:
            self.stats.forced_syncs += 1
        return self.sender.transmit_new(payload, FrameType.SYNC if sync else FrameType.DATA)

    def flush(self) -> bool:
        """Pump until every in-flight frame is acked (go-back-N timeouts:
        after ``timeout`` quiet ticks, retransmit all unacked frames, with
        exponential backoff). Returns False if the retry budget ran out.
        Traced as a ``link/<name>/flush`` span when a tracker is attached."""
        from repro.obs.trace import maybe_attr, maybe_span

        r0, rs0 = self.stats.retries, self.stats.resyncs
        with maybe_span(self.tracker, f"link/{self.name}/flush",
                        inflight=self.inflight) as sp:
            ok = self._flush()
            maybe_attr(sp, delivered=ok, retries=self.stats.retries - r0,
                       resyncs=self.stats.resyncs - rs0)
        return ok

    def _flush(self) -> bool:
        target = self.sender.next_seq
        timeout = self.timeout
        start = self.data.now
        for attempt in range(self.max_retries + 1):
            for _ in range(timeout):
                self._pump()
                if self.sender.acked_upto >= target:
                    if attempt > 0:
                        self.stats.recovery_ticks.append(self.data.now - start)
                    return True
            if attempt < self.max_retries:
                for s in range(self.sender.acked_upto, target):
                    if not self.sender.retransmit(s):
                        break
                timeout = max(1, math.ceil(timeout * self.backoff))
        self.stats.delivery_failures += target - self.sender.acked_upto
        self.sender._flag_resync()
        return False

    @property
    def inflight(self) -> int:
        return self.sender.next_seq - self.sender.acked_upto

    def recv(self) -> List[bytes]:
        """Pop every payload delivered in order so far."""
        out = list(self.receiver.delivered)
        self.receiver.delivered.clear()
        return out

    def _pump(self) -> None:
        """One virtual tick: move data frames forward, control frames back."""
        for raw in self.data.poll():
            for ctrl in self.receiver.on_frame(raw):
                self.ack.send(ctrl)
        for raw in self.ack.poll():
            self.sender.on_control(raw)

    def settle(self, ticks: int = 8) -> None:
        """Drain in-flight traffic (late stragglers, duplicate copies)."""
        for _ in range(ticks):
            self._pump()


class Fleet:
    """One reliable link per worker + fleet-wide counters for repro.obs."""

    def __init__(self, links: List[Link]) -> None:
        self.links = links

    @classmethod
    def make(
        cls,
        n: int,
        fault_spec: Optional[FaultSpec] = None,
        *,
        ack_faults: bool = False,
        **link_kwargs,
    ) -> "Fleet":
        """n links; worker i's injector is seeded ``spec.seed + i`` so the
        fleet shares one failure model but not one fault stream."""
        links = []
        for i in range(n):
            spec = fault_spec.with_seed(fault_spec.seed + i) if fault_spec else None
            aspec = (
                fault_spec.with_seed(fault_spec.seed + 10_000 + i)
                if (fault_spec and ack_faults)
                else None
            )
            links.append(Link(fault_spec=spec, ack_fault_spec=aspec,
                              name=f"worker{i}", **link_kwargs))
        return cls(links)

    def __len__(self) -> int:
        return len(self.links)

    def __iter__(self):
        return iter(self.links)

    @property
    def resync_needed(self) -> bool:
        return any(l.resync_needed for l in self.links)

    def attach_tracker(self, tracker) -> None:
        """Point every link's span instrumentation at ``tracker`` (§10).
        Link sends running inside an open round span parent under it."""
        for l in self.links:
            l.tracker = tracker

    def send_per_worker(self, payloads: List[bytes], *, sync: bool = False) -> List[bool]:
        assert len(payloads) == len(self.links)
        return [l.send(p, sync=sync) for l, p in zip(self.links, payloads)]

    def broadcast(self, payload: bytes, *, sync: bool = False) -> List[bool]:
        return [l.send(payload, sync=sync) for l in self.links]

    def drain(self) -> List[List[bytes]]:
        return [l.recv() for l in self.links]

    def stats(self) -> LinkStats:
        total = LinkStats()
        for l in self.links:
            total.merge(l.stats)
        return total

    def injected_counts(self) -> Dict[str, int]:
        """Fault-injector ground truth (what the channels actually did)."""
        out: Dict[str, int] = {}
        for l in self.links:
            for ch in (l.data, l.ack):
                if isinstance(ch, FaultyChannel):
                    for k, v in ch.counts.items():
                        out[k] = out.get(k, 0) + v
        return out

    def log_to(self, tracker, *, step: Optional[int] = None) -> Dict[str, float]:
        metrics = self.stats().as_metrics()
        if tracker is not None:
            tracker.log(metrics, step=step)
        return metrics
