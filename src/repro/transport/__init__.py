"""repro.transport — fault-tolerant framing + delivery for wire messages.

The layer between the repro.wire codecs (byte buffers) and the training /
serving loops that must survive a lossy WAN (DESIGN.md §8):

* :mod:`~repro.transport.frame` — length-prefixed frames with CRC32C
  trailers and monotonic sequence numbers;
* :mod:`~repro.transport.channel` — the Channel protocol, in-process
  loopback, and the fault-injecting wrapper;
* :mod:`~repro.transport.faults` — seeded :class:`FaultSpec` failure
  models (drop / corrupt / truncate / duplicate / reorder / straggler);
* :mod:`~repro.transport.link` — reliable delivery: retry with
  exponential backoff, bounded replay, receiver gap detection, and the
  resync handshake that lets MARINA-P promote its next round to a full
  sync broadcast (and EF21-P re-anchor its shift) instead of dying.
"""
from .channel import Channel, FaultyChannel, LoopbackChannel  # noqa: F401
from .faults import FAULT_CLASSES, FaultInjector, FaultSpec  # noqa: F401
from .frame import (  # noqa: F401
    CRC_BYTES,
    FRAME_OVERHEAD,
    HEADER_BYTES,
    Frame,
    FrameType,
    crc32c,
    decode_frame,
    encode_frame,
    is_frame,
)
from .link import (  # noqa: F401
    DeliveryFailed,
    Fleet,
    Link,
    LinkStats,
    SequenceGap,
    StaleDelta,
    TransportError,
)
