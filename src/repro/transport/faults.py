"""Seeded fault injection for transport channels (DESIGN.md §8.2).

A :class:`FaultSpec` names the failure model — per-frame probabilities for
every fault class the paper's WAN setting implies (lossy links, flaky
clients, stragglers) — and a :class:`FaultInjector` turns it into a
deterministic plan: given one outbound buffer, which (possibly damaged)
copies reach the channel and with what extra latency. Everything derives
from one ``numpy`` Generator seeded by ``spec.seed``, so a chaos run is
exactly reproducible and CI can assert on its counters.

Fault classes:

* **drop** — the frame never arrives;
* **corrupt** — one random bit is flipped (caught by the frame CRC32C);
* **truncate** — the tail is cut at a random byte (caught by the length
  prefix);
* **duplicate** — a second copy arrives one tick later;
* **reorder** — delivery is delayed 1..reorder_window ticks, so later
  sends overtake it;
* **straggler** — delivery is delayed ``straggler_ticks`` ticks, modeling
  a slow client link (the sender's retry timeout decides whether the
  round waits or proceeds without it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-frame fault probabilities + the RNG seed that fixes the run."""

    drop: float = 0.0
    corrupt: float = 0.0
    truncate: float = 0.0
    duplicate: float = 0.0
    reorder: float = 0.0
    reorder_window: int = 4
    straggler: float = 0.0
    straggler_ticks: int = 8
    seed: int = 0

    def with_seed(self, seed: int) -> "FaultSpec":
        return dataclasses.replace(self, seed=seed)

    @property
    def any_faults(self) -> bool:
        return any(
            p > 0
            for p in (self.drop, self.corrupt, self.truncate, self.duplicate,
                      self.reorder, self.straggler)
        )


#: fault classes reported in ``FaultInjector.counts``
FAULT_CLASSES = ("drop", "corrupt", "truncate", "duplicate", "reorder", "straggler")


class FaultInjector:
    """Deterministic per-frame fault planner for one channel direction."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.rng = np.random.default_rng(spec.seed)
        self.counts: Dict[str, int] = {k: 0 for k in FAULT_CLASSES}

    def plan(self, buf: bytes) -> List[Tuple[int, bytes]]:
        """Map one outbound buffer to [(delay_ticks, delivered_bytes), ...].

        An empty list means the frame was dropped. Corruption and
        truncation are mutually exclusive (one damage event per frame);
        delays compose (a duplicated straggler arrives late twice).
        """
        s, rng = self.spec, self.rng
        if s.drop > 0 and rng.random() < s.drop:
            self.counts["drop"] += 1
            return []
        out = buf
        if s.corrupt > 0 and rng.random() < s.corrupt:
            self.counts["corrupt"] += 1
            out = self._flip_bit(out)
        elif s.truncate > 0 and rng.random() < s.truncate:
            self.counts["truncate"] += 1
            out = out[: int(rng.integers(0, max(len(out), 1)))]
        delay = 0
        if s.reorder > 0 and rng.random() < s.reorder:
            self.counts["reorder"] += 1
            delay += int(rng.integers(1, s.reorder_window + 1))
        if s.straggler > 0 and rng.random() < s.straggler:
            self.counts["straggler"] += 1
            delay += s.straggler_ticks
        deliveries = [(delay, out)]
        if s.duplicate > 0 and rng.random() < s.duplicate:
            self.counts["duplicate"] += 1
            deliveries.append((delay + 1, bytes(out)))
        return deliveries

    def _flip_bit(self, buf: bytes) -> bytes:
        if not buf:
            return buf
        i = int(self.rng.integers(0, len(buf)))
        bit = 1 << int(self.rng.integers(0, 8))
        out = bytearray(buf)
        out[i] ^= bit
        return bytes(out)
