"""Pytree checkpointing without orbax: npz payload + json tree manifest.

Leaves are stored flat (key = /-joined tree path) in a single compressed
``.npz``; structure and dtypes round-trip exactly. Atomic via rename.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(jax.tree_util.keystr((p,)).strip("[]'\".") for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(path: str, tree: Any, step: int = 0, extra: Dict | None = None):
    arrays, _ = _flatten_with_paths(tree)
    meta = {"step": int(step), "keys": sorted(arrays.keys()), "extra": extra or {}}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".tmp")
    os.close(fd)
    try:
        np.savez_compressed(tmp, __meta__=json.dumps(meta), **arrays)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for t in (tmp, tmp + ".npz"):
            if os.path.exists(t):
                os.remove(t)


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    with np.load(path, allow_pickle=False) as zf:
        meta = json.loads(str(zf["__meta__"]))
        arrays = {k: zf[k] for k in meta["keys"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_, leaf in flat:
        key = "/".join(jax.tree_util.keystr((p,)).strip("[]'\".") for p in path_)
        arr = arrays[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
