"""Fused L1 subgradient kernel:  g = A^T sign(A x)  (Pallas TPU).

The inner oracle of the paper's experiment workload f_i(x) = ||A_i x||_1
(App. A): both matvecs and the sign fused in one kernel so the [d] intermediate
y = A x never round-trips to HBM.

Tiling: grid over row-blocks of A; per step an [R, d] tile of A and the full
x, y_r = A_r x; g accumulates A_r^T sign(y_r) across grid steps (output
revisited each step — Pallas sequential-grid accumulation). R and d must be
multiples of 8/128 respectively; the paper's d=1000 is padded to 1024 by
ops.py. sign(0)=+1 per paper eq. (32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret


def _l1_subgrad_kernel(a_ref, x_ref, g_ref):
    i = pl.program_id(0)
    a = a_ref[...]  # [R, d]
    x = x_ref[...]  # [1, d]
    y = jnp.dot(a, x[0], preferred_element_type=jnp.float32)  # [R]
    s = jnp.where(y >= 0, 1.0, -1.0)
    contrib = jnp.dot(s, a, preferred_element_type=jnp.float32)  # [d]

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    g_ref[...] += contrib[None, :].astype(g_ref.dtype)


def l1_subgrad(A: jax.Array, x: jax.Array, *, row_block: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """A: [m, d] (m % row_block == 0, d % 128 == 0); x: [d] -> g: [d]."""
    interpret = resolve_interpret(interpret)
    m, d = A.shape
    assert m % row_block == 0 and d % 128 == 0, (m, d)
    grid = (m // row_block,)
    out = pl.pallas_call(
        _l1_subgrad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(A, x[None, :])
    return out[0]
