"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to the shared policy in kernels/runtime.py: True
off-TPU (this container is CPU-only; the kernel bodies execute in Python
for correctness validation), False on a real TPU backend, overridable via
``REPRO_PALLAS_INTERPRET``. Shapes are padded to tile multiples and
unpadded here so callers can pass arbitrary d (e.g. the paper's d=1000).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import l1_subgrad as _l1
from . import pack as _pack
from . import permk as _permk
from . import randk as _randk
from . import topk as _topk
from .runtime import default_interpret as _default_interpret


def _pad_to(x, mult):
    d = x.shape[-1]
    pad = (-d) % mult
    return (jnp.pad(x, (0, pad)), d) if pad else (x, d)


@partial(jax.jit, static_argnames=("k_per_block", "block", "interpret"))
def block_topk(x, *, k_per_block: int, block: int = 1024, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    xp, d = _pad_to(x, block)
    out = _topk.block_topk_compress(xp, k_per_block=k_per_block, block=block, interpret=interpret)
    return out[:d]


@partial(jax.jit, static_argnames=("keep_prob", "seed", "worker", "block", "interpret"))
def bernk(x, *, keep_prob: float, seed: int, worker: int = 0, block: int = 1024,
          interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    xp, d = _pad_to(x, block)
    out = _randk.bernk_compress(
        xp, keep_prob=keep_prob, seed=seed, worker=worker, block=block, interpret=interpret
    )
    return out[:d]


@partial(jax.jit, static_argnames=("n", "worker", "block", "interpret"))
def rotk_apply(w, delta, rotation, *, n: int, worker: int, block: int = 1024,
               interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    wp, d = _pad_to(w, block)
    dp, _ = _pad_to(delta, block)
    out = _permk.rotk_apply(wp, dp, rotation, n=n, worker=worker, block=block, interpret=interpret)
    return out[:d]


@partial(jax.jit, static_argnames=("width", "interpret"))
def pack_bits(values, *, width: int, interpret: bool | None = None):
    """Bit-pack ``values`` ([n] uint32, each < 2**width) into uint32 words
    (wire/bitstream.py layout). Zero-pads to block multiples and trims the
    output to ceil(n*width/32) words."""
    interpret = _default_interpret() if interpret is None else interpret
    vpb, _ = _pack.word_block(width)
    vp, n = _pad_to(values.astype(jnp.uint32), vpb)
    nwords = -(-n * width // 32)
    return _pack.pack_bits_device(vp, width=width, interpret=interpret)[:nwords]


@partial(jax.jit, static_argnames=("width", "count", "interpret"))
def unpack_bits(words, *, width: int, count: int, interpret: bool | None = None):
    """Inverse of :func:`pack_bits`: read ``count`` values of ``width`` bits."""
    interpret = _default_interpret() if interpret is None else interpret
    _, wpb = _pack.word_block(width)
    wp, _ = _pad_to(words.astype(jnp.uint32), wpb)
    return _pack.unpack_bits_device(wp, width=width, interpret=interpret)[:count]


@partial(jax.jit, static_argnames=("row_block", "interpret"))
def l1_subgrad(A, x, *, row_block: int = 128, interpret: bool | None = None):
    """g = A^T sign(A x), padded to (row_block, 128) tiles. A: [m, d]."""
    interpret = _default_interpret() if interpret is None else interpret
    m, d = A.shape
    pm, pd = (-m) % row_block, (-d) % 128
    Ap = jnp.pad(A, ((0, pm), (0, pd)))
    xp = jnp.pad(x, (0, pd))
    # NOTE: zero-pad rows give sign(0)=+1 contributions of zero rows => A_pad^T
    # row is zero, so padding is exact.
    g = _l1.l1_subgrad(Ap, xp, row_block=row_block, interpret=interpret)
    return g[:d]
