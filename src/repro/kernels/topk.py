"""Block-local magnitude TopK compression kernel (Pallas TPU).

TPU adaptation of the paper's TopK contractive compressor (DESIGN.md §2):
global top-k needs a sequential selection over d elements; the TPU-native
variant selects the top ``k`` per contiguous block of ``b`` elements, one
block per grid step, entirely in VMEM. Contraction factor alpha = k/b
(Definition 3 holds per block, hence globally).

Selection is exact iterative extraction: k rounds of (masked) argmax with
first-index tie-breaking — bit-identical to ``jax.lax.top_k`` semantics, so
the pure-jnp oracle in ref.py matches exactly.

Tiling: x is viewed as [nblocks, b]; BlockSpec (1, b) keeps one block in
VMEM per grid step; b must be a multiple of 128 (lane width).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret


def _topk_block_kernel(x_ref, out_ref, *, k: int):
    x = x_ref[...]  # [1, b]
    b = x.shape[-1]
    absx = jnp.abs(x)
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    def body(_, carry):
        remaining, keep = carry
        # first-index tie-break: pick smallest idx among maxima
        m = jnp.max(remaining)
        is_max = remaining == m
        first = jnp.min(jnp.where(is_max, idx, b))
        sel = idx == first
        return remaining * (1.0 - sel) - sel, keep | sel

    keep0 = jnp.zeros(x.shape, dtype=jnp.bool_)
    _, keep = jax.lax.fori_loop(0, k, body, (absx.astype(jnp.float32), keep0))
    out_ref[...] = jnp.where(keep, x, 0.0).astype(out_ref.dtype)


def block_topk_compress(x: jax.Array, *, k_per_block: int, block: int = 1024,
                        interpret: bool | None = None) -> jax.Array:
    """x: [d] (d % block == 0). Returns the sparsified vector (dense layout)."""
    interpret = resolve_interpret(interpret)
    d = x.shape[-1]
    assert d % block == 0, (d, block)
    nblocks = d // block
    xb = x.reshape(nblocks, block)
    out = pl.pallas_call(
        functools.partial(_topk_block_kernel, k=k_per_block),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), x.dtype),
        interpret=interpret,
    )(xb)
    return out.reshape(d)
