"""On-device bitstream pack/unpack kernels (Pallas TPU).

Implements the wire format's bit layout (repro/wire/bitstream.py: LSB-first
into little-endian uint32 words) on-device, so index/value streams of a
sparse downlink message can be packed before ever touching the host
(DESIGN.md §3.4). Bit-interchangeable with the host numpy codec — asserted
in tests/test_wire.py.

Tiling: blocks are chosen word-aligned (values_per_block * width % 32 == 0),
so no value crosses a block boundary and each grid step packs its own word
range independently. Inside a block, value ``i`` contributes a low part to
word ``(i*width) // 32`` and (when it straddles) a high part to the next
word; the kernel accumulates both with a broadcast compare-and-sum — pure
vector ops, no scatter — which lowers to VPU code on TPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret


def word_block(width: int, target: int = 512) -> tuple[int, int]:
    """(values_per_block, words_per_block): the smallest word-aligned value
    group, replicated up to ~``target`` values per grid step."""
    g = math.gcd(width, 32)
    gv, gw = 32 // g, width // g  # values / words per aligned group
    reps = max(1, target // gv)
    return gv * reps, gw * reps


def _split_parts(v, width: int):
    """Per-value (low word part, high word part, local word index)."""
    vpb = v.shape[-1]
    i = jax.lax.broadcasted_iota(jnp.int32, v.shape, len(v.shape) - 1)
    pos = i * width
    word = pos // 32
    off = (pos % 32).astype(jnp.uint32)
    lo = v << off  # uint32: overflow bits drop, as intended
    hi = (v >> jnp.uint32(1)) >> (jnp.uint32(31) - off)  # v >> (32-off); off=0 -> 0
    return lo, hi, word


def _pack_kernel(v_ref, out_ref, *, width: int, wpb: int):
    v = v_ref[...].astype(jnp.uint32)  # [1, vpb]
    lo, hi, word = _split_parts(v, width)
    j = jax.lax.broadcasted_iota(jnp.int32, (1, v.shape[-1], wpb), 2)
    wcol = word[..., None]  # [1, vpb, 1]
    acc = jnp.where(j == wcol, lo[..., None], jnp.uint32(0))
    acc = acc + jnp.where(j == wcol + 1, hi[..., None], jnp.uint32(0))
    out_ref[...] = jnp.sum(acc, axis=1).astype(jnp.uint32)  # [1, wpb]


def _unpack_kernel(w_ref, out_ref, *, width: int, vpb: int):
    w = w_ref[...].astype(jnp.uint32)  # [1, wpb]
    wpb = w.shape[-1]
    i = jax.lax.broadcasted_iota(jnp.int32, (1, vpb), 1)
    pos = i * width
    word = pos // 32
    off = (pos % 32).astype(jnp.uint32)
    j = jax.lax.broadcasted_iota(jnp.int32, (1, vpb, wpb), 2)
    wcol = word[..., None]
    cur = jnp.sum(jnp.where(j == wcol, w[:, None, :], jnp.uint32(0)), axis=2)
    nxt = jnp.sum(jnp.where(j == wcol + 1, w[:, None, :], jnp.uint32(0)), axis=2)
    lo = cur >> off
    hi = (nxt << jnp.uint32(1)) << (jnp.uint32(31) - off)  # nxt << (32-off); off=0 -> 0
    mask = jnp.uint32(0xFFFFFFFF if width == 32 else (1 << width) - 1)
    out_ref[...] = ((lo | hi) & mask).astype(jnp.uint32)


def pack_bits_device(values: jax.Array, *, width: int,
                     interpret: bool | None = None) -> jax.Array:
    """values: [n] uint32 (n % values_per_block == 0). Returns packed words.

    ``interpret=None`` auto-detects via kernels/runtime.py (compiled on a
    real TPU, interpret under CPU tests; ``REPRO_PALLAS_INTERPRET`` forces).
    """
    interpret = resolve_interpret(interpret)
    vpb, wpb = word_block(width)
    n = values.shape[-1]
    assert n % vpb == 0, (n, vpb)
    nblocks = n // vpb
    out = pl.pallas_call(
        functools.partial(_pack_kernel, width=width, wpb=wpb),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((1, vpb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, wpb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, wpb), jnp.uint32),
        interpret=interpret,
    )(values.reshape(nblocks, vpb))
    return out.reshape(nblocks * wpb)


def unpack_bits_device(words: jax.Array, *, width: int,
                       interpret: bool | None = None) -> jax.Array:
    """words: [nw] uint32 (nw % words_per_block == 0). Returns unpacked values."""
    interpret = resolve_interpret(interpret)
    vpb, wpb = word_block(width)
    nw = words.shape[-1]
    assert nw % wpb == 0, (nw, wpb)
    nblocks = nw // wpb
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, width=width, vpb=vpb),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((1, wpb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, vpb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, vpb), jnp.uint32),
        interpret=interpret,
    )(words.reshape(nblocks, wpb))
    return out.reshape(nblocks * vpb)
