"""Fused on-device compressor -> bitstream encode kernels (Pallas TPU).

The host codecs (repro/wire) top out around ~0.5 GB/s, which makes encoding
the N per-worker compressed broadcasts of a MARINA-P round the downlink
bottleneck at scale (ROADMAP "on-device encode path and codec speed").
The kernels here fuse compressor selection and stream extraction into one
VMEM pass and bit-pack with the word-aligned compare-and-sum layout of
``kernels/pack.py``, so the packed uint32 words leave the device
send-ready; the host contributes only the 16 fixed header/payload bytes.

Fused paths — each **byte-identical** to the host codec on every input
(asserted by the differential harness in tests/test_encode_diff.py):

* :func:`topk_encode`  — block-TopK select -> (index, sign, magnitude)
  streams -> packed words, ``== wire.encode_sparse(ops.block_topk(x))``.
  Selection reuses kernels/topk.py's iterative-extraction semantics
  (first-index tie-break, bit-identical to ``jax.lax.top_k``).
* :func:`mask_encode`  — BernK counter-hash mask + scale + streams, seeded
  on-device with ``kernels/randk.hash_uniform`` so the mask bit-matches the
  SEED codec's receiver-side rematerialization (wire/seedonly.py, BERN
  family with ``seed + round`` folded by the caller).
* :func:`sparse_encode` — streams for an arbitrary already-sparsified
  vector (the ``measure_wire`` call sites hold Q on device already).
* :func:`dense_encode` — DENSE codec payload for full-sync rounds.
* :func:`encode_rows` / :func:`encode_per_worker` — batched N-stream paths
  (vmap over message rows / the on-device worker id) amortizing the
  per-round fan-out of MARINA-style per-worker messages.

Dynamic sizing: the SPARSE layout is compacted by nonzero count, so one
scalar per message is read back to trim the word streams; everything else
stays on device with static shapes. Compaction is a stable argsort on the
validity mask (kept entries first, ascending index — exactly
``np.nonzero`` order), which batches under ``jax.vmap`` unchanged.

``device_encode_enabled`` is the routing policy for the integration points
(wire/registry.py, core runs, train/downlink.py, fleet/cohort.py):
explicit override > ``REPRO_DEVICE_ENCODE`` env (1/0/auto) > backend
auto-detect (on for TPU, off for the interpret-mode CPU fallback, where
the numpy codec is faster).
"""
from __future__ import annotations

import functools
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.wire import bitstream as bs
from repro.wire.spec import (
    MAG_BITS,
    CodecID,
    MagDType,
    index_width,
    mag_dtype,
    pack_header,
)

from . import pack as _pack
from .randk import hash_uniform
from .runtime import resolve_interpret

# Payload layouts mirror wire/sparse.py (the single source of the byte
# format is DESIGN.md §3.1/§3.4; these structs must match _PAYLOAD there).
_SPARSE_PAYLOAD = struct.Struct("<BxxxI")  # [u8 mag][pad x3][u32 count]
_DENSE_PAYLOAD = struct.Struct("<Bxxx")    # [u8 mag][pad x3]

DEVICE_ENCODE_ENV = "REPRO_DEVICE_ENCODE"


def device_encode_enabled(override: bool | None = None) -> bool:
    """Should an encode call site route through the fused device path?

    Precedence: explicit ``override`` > ``REPRO_DEVICE_ENCODE`` (1/0/auto)
    > backend auto-detect. Auto is on only for a real TPU backend: in
    interpret mode the Pallas bodies run as traced Python, where the host
    numpy codec is faster — the device path is for real accelerators (and
    for the differential/byte-identity tests, which force it on).
    """
    if override is not None:
        return bool(override)
    v = os.environ.get(DEVICE_ENCODE_ENV, "auto").strip().lower()
    if v in ("1", "true", "on", "yes"):
        return True
    if v in ("0", "false", "off", "no"):
        return False
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------


def _valbits(v, m: MagDType):
    """Bit pattern of ``v`` in the wire magnitude dtype, widened to u32.

    Matches the host codec's ``v.astype(fdt).view(udt)`` exactly: one
    round-to-nearest-even cast, then a pure bitcast.
    """
    if m == MagDType.FP32:
        return jax.lax.bitcast_convert_type(v, jnp.uint32)
    fdt = jnp.float16 if m == MagDType.FP16 else jnp.bfloat16
    return jax.lax.bitcast_convert_type(v.astype(fdt), jnp.uint16).astype(jnp.uint32)


def _emit_stream_bits(bits, sign_ref, mag_ref, valid_ref, m: MagDType):
    """Shared SPARSE-stream epilogue over f32 *bit patterns*.

    Works on bits, not floats, because the host codec's primitives are all
    bitwise (np.signbit = bit 31, np.abs = clear bit 31, np.nonzero =
    magnitude bits != 0) while XLA CPU flushes denormals to zero in float
    arithmetic/compares — a ``val != 0`` here would silently elide a
    denormal payload the host codec keeps. NaN/inf/-0.0 fall out exactly:
    -0.0 has zero magnitude bits (elided like the host), NaN magnitude
    bits are nonzero (kept like the host).
    """
    sign_ref[...] = bits >> jnp.uint32(31)
    magbits = bits & jnp.uint32(0x7FFFFFFF)
    if m == MagDType.FP32:
        mag_ref[...] = magbits
    else:
        mag_ref[...] = _valbits(
            jax.lax.bitcast_convert_type(magbits, jnp.float32), m
        )
    valid_ref[...] = (magbits != 0).astype(jnp.uint32)


def _emit_streams(val, sign_ref, mag_ref, valid_ref, m: MagDType):
    bits = jax.lax.bitcast_convert_type(val, jnp.uint32)
    _emit_stream_bits(bits, sign_ref, mag_ref, valid_ref, m)


def _sparse_streams_kernel(x_ref, sign_ref, mag_ref, valid_ref, *, m: MagDType):
    """Streamify an arbitrary (already sparsified) vector block."""
    _emit_streams(x_ref[...], sign_ref, mag_ref, valid_ref, m)


def _mask_streams_kernel(x_ref, w_ref, sign_ref, mag_ref, valid_ref, *,
                         keep_prob: float, seed: int, block: int, m: MagDType):
    """Fused BernK: counter-hash mask + scale + streamify in one pass.

    The hash is kernels/randk.hash_uniform on the *global* index, so the
    mask is bit-identical to ops.bernk and to the SEED codec's
    receiver-side rematerialization. ``worker`` is a runtime operand (not
    a closure static) so the per-worker fan-out batches under vmap.
    """
    i = pl.program_id(0)
    x = x_ref[...]  # [1, b]
    worker = w_ref[0]
    local = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    gidx = (i * block + local).astype(jnp.uint32)
    u = hash_uniform(gidx, seed, worker)
    val = jnp.where(u < keep_prob, x / keep_prob, 0.0)
    _emit_streams(val, sign_ref, mag_ref, valid_ref, m)


def _topk_streams_kernel(x_ref, idx_ref, sign_ref, mag_ref, valid_ref, *,
                         k: int, block: int, m: MagDType):
    """Fused block-TopK: select + compact + streamify in one VMEM pass.

    Selection is the exact iterative extraction of kernels/topk.py (k
    rounds of masked argmax, first-index tie-break). The selected entries
    are then compacted into the leading ``k`` output slots in ascending
    index order — a rank (cumsum of the keep mask) equality against a
    broadcast slot iota, the same scatter-free compare-and-sum idiom as
    kernels/pack.py — so the concatenated per-block streams are already in
    global np.nonzero order.
    """
    i = pl.program_id(0)
    x = x_ref[...]  # [1, b]
    b = x.shape[-1]
    absx = jnp.abs(x)
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    def body(_, carry):
        remaining, keep = carry
        mx = jnp.max(remaining)
        is_max = remaining == mx
        first = jnp.min(jnp.where(is_max, idx, b))
        sel = idx == first
        return remaining * (1.0 - sel) - sel, keep | sel

    keep0 = jnp.zeros(x.shape, dtype=jnp.bool_)
    _, keep = jax.lax.fori_loop(0, k, body, (absx.astype(jnp.float32), keep0))

    ks = min(k, b)  # slots: never more than the block holds
    rank = jnp.cumsum(keep.astype(jnp.int32), axis=-1) - 1          # [1, b]
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, ks, b), 1)
    hit = keep[:, None, :] & (rank[:, None, :] == slot)             # [1, ks, b]
    # gather via integer compare-and-sum on the f32 bit patterns: exact for
    # every payload (denormals would not survive a float-sum under FTZ)
    xbits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    valbits = jnp.sum(jnp.where(hit, xbits[:, None, :], jnp.uint32(0)), axis=2)
    gidx = (i * block + idx).astype(jnp.uint32)
    idx_ref[...] = jnp.sum(jnp.where(hit, gidx[:, None, :], jnp.uint32(0)), axis=2)
    _emit_stream_bits(valbits, sign_ref, mag_ref, valid_ref, m)


def _dense_bits_kernel(x_ref, out_ref, *, m: MagDType):
    """DENSE codec pass: raw value -> wire-dtype bit pattern (sign kept)."""
    out_ref[...] = _valbits(x_ref[...], m)


# ---------------------------------------------------------------------------
# device pipelines (jitted, static shapes; the count is a traced scalar)
# ---------------------------------------------------------------------------


def _pad_to(x, mult):
    d = x.shape[-1]
    pad = (-d) % mult
    return (jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]), d) if pad else (x, d)


def _block_spec(block):
    return pl.BlockSpec((1, block), lambda i: (i, 0))


def _pack_stream(vals, *, width: int, interpret: bool):
    """Word-pack a full-length stream on device; returns every word the
    stream could need (callers trim to ``n_words(count, width)``)."""
    n = vals.shape[-1]
    if n == 0:
        return jnp.zeros((0,), jnp.uint32)
    vpb, _ = _pack.word_block(width)
    pad = (-n) % vpb
    vp = jnp.pad(vals, (0, pad)) if pad else vals
    nwords = -(-n * width // 32)
    return _pack.pack_bits_device(vp, width=width, interpret=interpret)[:nwords]


def _compact_streams(idx, sign, mag, valid):
    """Move valid entries to the front in ascending-index order and zero
    everything behind the count (so packing the full-length stream leaves
    only zero bits past ``count * width`` — the host codec's padding)."""
    order = jnp.argsort(jnp.logical_not(valid.astype(bool)), axis=-1, stable=True)
    take = functools.partial(jnp.take_along_axis, indices=order, axis=-1)
    idx, sign, mag = take(idx), take(sign), take(mag)
    count = jnp.sum(valid, axis=-1).astype(jnp.uint32)
    live = (
        jax.lax.broadcasted_iota(jnp.uint32, idx.shape, idx.ndim - 1)
        < count[..., None]
    ).astype(jnp.uint32)
    return idx * live, sign * live, mag * live, count


def _pack_sparse(idx, sign, mag, valid, *, iw: int, m: MagDType, interpret: bool):
    idx, sign, mag, count = _compact_streams(idx, sign, mag, valid)
    return (
        count,
        _pack_stream(idx, width=iw, interpret=interpret),
        _pack_stream(sign, width=1, interpret=interpret),
        _pack_stream(mag, width=MAG_BITS[m], interpret=interpret),
    )


@functools.partial(jax.jit, static_argnames=("m", "block", "iw", "interpret"))
def _sparse_device(x, *, m: MagDType, block: int, iw: int, interpret: bool):
    xp, d = _pad_to(x.astype(jnp.float32), block)
    nblocks = xp.shape[-1] // block
    sign, mag, valid = pl.pallas_call(
        functools.partial(_sparse_streams_kernel, m=m),
        grid=(nblocks,),
        in_specs=[_block_spec(block)],
        out_specs=[_block_spec(block)] * 3,
        out_shape=[jax.ShapeDtypeStruct((nblocks, block), jnp.uint32)] * 3,
        interpret=interpret,
    )(xp.reshape(nblocks, block))
    idx = jnp.arange(nblocks * block, dtype=jnp.uint32)
    flat = lambda a: a.reshape(-1)
    return _pack_sparse(idx, flat(sign), flat(mag), flat(valid),
                        iw=iw, m=m, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("keep_prob", "seed", "m", "block", "iw", "interpret")
)
def _mask_device(x, worker, *, keep_prob: float, seed: int, m: MagDType,
                 block: int, iw: int, interpret: bool):
    """``worker`` is a [1] int32 operand — vmap it for the per-worker path."""
    xp, d = _pad_to(x.astype(jnp.float32), block)
    nblocks = xp.shape[-1] // block
    sign, mag, valid = pl.pallas_call(
        functools.partial(_mask_streams_kernel, keep_prob=keep_prob, seed=seed,
                          block=block, m=m),
        grid=(nblocks,),
        in_specs=[_block_spec(block), pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[_block_spec(block)] * 3,
        out_shape=[jax.ShapeDtypeStruct((nblocks, block), jnp.uint32)] * 3,
        interpret=interpret,
    )(xp.reshape(nblocks, block), worker)
    idx = jnp.arange(nblocks * block, dtype=jnp.uint32)
    flat = lambda a: a.reshape(-1)
    return _pack_sparse(idx, flat(sign), flat(mag), flat(valid),
                        iw=iw, m=m, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("k_per_block", "m", "block", "iw", "interpret")
)
def _topk_device(x, *, k_per_block: int, m: MagDType, block: int, iw: int,
                 interpret: bool):
    xp, d = _pad_to(x.astype(jnp.float32), block)
    nblocks = xp.shape[-1] // block
    ks = min(k_per_block, block)
    out_spec = pl.BlockSpec((1, ks), lambda i: (i, 0))
    idx, sign, mag, valid = pl.pallas_call(
        functools.partial(_topk_streams_kernel, k=k_per_block, block=block, m=m),
        grid=(nblocks,),
        in_specs=[_block_spec(block)],
        out_specs=[out_spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((nblocks, ks), jnp.uint32)] * 4,
        interpret=interpret,
    )(xp.reshape(nblocks, block))
    flat = lambda a: a.reshape(-1)
    return _pack_sparse(flat(idx), flat(sign), flat(mag), flat(valid),
                        iw=iw, m=m, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("m", "block", "interpret"))
def _dense_device(x, *, m: MagDType, block: int, interpret: bool):
    xp, d = _pad_to(x.astype(jnp.float32), block)
    nblocks = xp.shape[-1] // block
    bits = pl.pallas_call(
        functools.partial(_dense_bits_kernel, m=m),
        grid=(nblocks,),
        in_specs=[_block_spec(block)],
        out_specs=_block_spec(block),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), jnp.uint32),
        interpret=interpret,
    )(xp.reshape(nblocks, block))
    return _pack_stream(bits.reshape(-1)[:d], width=MAG_BITS[m], interpret=interpret)


# ---------------------------------------------------------------------------
# host assembly (16 fixed bytes + trimmed device words)
# ---------------------------------------------------------------------------


def _assemble_sparse(d: int, m: MagDType, count, widx, wsign, wmag) -> bytes:
    count = int(count)
    head = pack_header(CodecID.SPARSE, d) + _SPARSE_PAYLOAD.pack(int(m), count)
    if count == 0:
        return head
    iw = index_width(d)
    # one transfer for all three streams, then zero-copy trims
    words = np.asarray(jnp.concatenate([widx, wsign, wmag]))
    o1, o2 = widx.shape[0], widx.shape[0] + wsign.shape[0]
    return head + b"".join(
        words[o : o + bs.n_words(count, w)].tobytes()
        for o, w in ((0, iw), (o1, 1), (o2, MAG_BITS[m]))
    )


def sparse_encode(x, *, mag="fp32", block: int = 1024,
                  interpret: bool | None = None) -> bytes:
    """SPARSE-codec encode of an already-sparsified vector, fully on
    device. Byte-identical to ``wire.encode_sparse(np.asarray(x))``."""
    m = mag_dtype(mag)
    x = jnp.asarray(x)
    d = x.shape[-1]
    if d == 0:
        return pack_header(CodecID.SPARSE, 0) + _SPARSE_PAYLOAD.pack(int(m), 0)
    count, widx, wsign, wmag = _sparse_device(
        x, m=m, block=block, iw=index_width(d),
        interpret=resolve_interpret(interpret),
    )
    return _assemble_sparse(d, m, count, widx, wsign, wmag)


def topk_encode(x, *, k_per_block: int, block: int = 1024, mag="fp32",
                interpret: bool | None = None) -> bytes:
    """Fused block-TopK compress + SPARSE encode. Byte-identical to
    ``wire.encode_sparse(ops.block_topk(x, k_per_block=..., block=...))``."""
    m = mag_dtype(mag)
    x = jnp.asarray(x)
    d = x.shape[-1]
    count, widx, wsign, wmag = _topk_device(
        x, k_per_block=k_per_block, m=m, block=block, iw=index_width(d),
        interpret=resolve_interpret(interpret),
    )
    return _assemble_sparse(d, m, count, widx, wsign, wmag)


def mask_encode(x, *, keep_prob: float, seed: int, worker: int = 0,
                block: int = 1024, mag="fp32",
                interpret: bool | None = None) -> bytes:
    """Fused BernK compress + SPARSE encode, seeded on device.

    Byte-identical to ``wire.encode_sparse(ops.bernk(x, keep_prob=...,
    seed=..., worker=...))``; the mask bit-matches the SEED codec's BERN
    rematerialization (pass ``seed = msg.seed + msg.round`` for parity
    with wire/seedonly.apply_seed).
    """
    m = mag_dtype(mag)
    x = jnp.asarray(x)
    d = x.shape[-1]
    count, widx, wsign, wmag = _mask_device(
        x, jnp.asarray([worker], jnp.int32), keep_prob=keep_prob, seed=seed,
        m=m, block=block, iw=index_width(d),
        interpret=resolve_interpret(interpret),
    )
    return _assemble_sparse(d, m, count, widx, wsign, wmag)


def dense_encode(x, *, mag="fp32", block: int = 1024,
                 interpret: bool | None = None) -> bytes:
    """DENSE-codec encode on device (full-sync broadcast rounds).
    Byte-identical to ``wire.encode_dense(np.asarray(x))``."""
    m = mag_dtype(mag)
    x = jnp.asarray(x)
    words = _dense_device(x, m=m, block=block,
                          interpret=resolve_interpret(interpret))
    return (
        pack_header(CodecID.DENSE, x.shape[-1])
        + _DENSE_PAYLOAD.pack(int(m))
        + np.asarray(words).tobytes()
    )


# ---------------------------------------------------------------------------
# batched fan-out paths
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("m", "block", "iw", "interpret"))
def _rows_device(X, *, m: MagDType, block: int, iw: int, interpret: bool):
    """vmap of the sparse pipeline over message rows [n, d]."""
    return jax.vmap(
        lambda row: _sparse_device(row, m=m, block=block, iw=iw,
                                   interpret=interpret)
    )(X)


@functools.partial(
    jax.jit, static_argnames=("keep_prob", "seed", "m", "block", "iw", "interpret")
)
def _workers_device(x, workers, *, keep_prob: float, seed: int, m: MagDType,
                    block: int, iw: int, interpret: bool):
    """vmap of the fused mask pipeline over the worker-id operand: one
    shared input vector, N packed streams."""
    return jax.vmap(
        lambda w: _mask_device(x, w, keep_prob=keep_prob, seed=seed, m=m,
                               block=block, iw=iw, interpret=interpret),
        in_axes=(0,),
    )(workers)


def _assemble_rows(d, m, counts, widx, wsign, wmag):
    return [
        _assemble_sparse(d, m, counts[i], widx[i], wsign[i], wmag[i])
        for i in range(len(counts))
    ]


def encode_rows(X, *, mag="fp32", block: int = 1024,
                interpret: bool | None = None) -> list[bytes]:
    """Batched :func:`sparse_encode` over message rows ``X [n, d]`` —
    one vmapped device pass, n send-ready buffers."""
    m = mag_dtype(mag)
    X = jnp.asarray(X)
    n, d = X.shape
    if d == 0:
        head = pack_header(CodecID.SPARSE, 0) + _SPARSE_PAYLOAD.pack(int(m), 0)
        return [head] * n
    counts, widx, wsign, wmag = _rows_device(
        X, m=m, block=block, iw=index_width(d),
        interpret=resolve_interpret(interpret),
    )
    return _assemble_rows(d, m, np.asarray(counts), widx, wsign, wmag)


def encode_per_worker(x, *, n_workers: int, keep_prob: float, seed: int,
                      mode: str = "ind", block: int = 1024, mag="fp32",
                      interpret: bool | None = None) -> list[bytes]:
    """N per-worker BernK streams from one shared input, batched on device.

    ``mode="ind"`` hashes each worker id independently (MARINA-P ind
    broadcast); ``mode="same"`` encodes worker 0 once and repeats the
    buffer (every message is identical). Each buffer is byte-identical to
    the matching :func:`mask_encode` call.
    """
    m = mag_dtype(mag)
    x = jnp.asarray(x)
    d = x.shape[-1]
    if mode == "same":
        buf = mask_encode(x, keep_prob=keep_prob, seed=seed, worker=0,
                          block=block, mag=mag, interpret=interpret)
        return [buf] * n_workers
    if mode != "ind":
        raise ValueError(f"encode_per_worker mode must be ind|same, got {mode!r}")
    workers = jnp.arange(n_workers, dtype=jnp.int32).reshape(n_workers, 1)
    counts, widx, wsign, wmag = _workers_device(
        x, workers, keep_prob=keep_prob, seed=seed, m=m, block=block,
        iw=index_width(d), interpret=resolve_interpret(interpret),
    )
    return _assemble_rows(d, m, np.asarray(counts), widx, wsign, wmag)
