"""Counter-based Bernoulli-K sparsification kernel (Pallas TPU).

The jit-friendly RandK stand-in (BernK, omega = d/k - 1) regenerated from a
counter hash *inside* the kernel — zero HBM traffic for randomness, the
TPU-native way to materialize the paper's downlink messages from shared
seeds (DESIGN.md §2). Hash: 3-round xorshift-multiply of (seed, worker,
global index); ref.py implements the identical hash in jnp so outputs match
bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret

_M1 = 2654435761
_M2 = 2246822519


def hash_uniform(idx: jax.Array, seed, worker) -> jax.Array:
    """Deterministic per-index uniform in [0,1). idx: uint32 array."""
    m1 = jnp.asarray(_M1, jnp.uint32)
    m2 = jnp.asarray(_M2, jnp.uint32)
    h = idx.astype(jnp.uint32) * m1
    h = h ^ (jnp.asarray(seed % (1 << 32), jnp.uint32) + jnp.asarray(worker, jnp.uint32) * m2)
    h = h ^ (h >> 15)
    h = h * m2
    h = h ^ (h >> 13)
    h = h * m1
    h = h ^ (h >> 16)
    return h.astype(jnp.float32) * (1.0 / 4294967296.0)


def _bernk_kernel(x_ref, out_ref, *, keep_prob: float, seed: int, worker: int, block: int):
    i = pl.program_id(0)
    x = x_ref[...]  # [1, b]
    local = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    gidx = (i * block + local).astype(jnp.uint32)
    u = hash_uniform(gidx, seed, worker)
    keep = u < keep_prob
    out_ref[...] = jnp.where(keep, x / keep_prob, 0.0).astype(out_ref.dtype)


def bernk_compress(x: jax.Array, *, keep_prob: float, seed: int, worker: int = 0,
                   block: int = 1024, interpret: bool | None = None) -> jax.Array:
    interpret = resolve_interpret(interpret)
    d = x.shape[-1]
    assert d % block == 0, (d, block)
    nblocks = d // block
    out = pl.pallas_call(
        functools.partial(
            _bernk_kernel, keep_prob=keep_prob, seed=seed, worker=worker, block=block
        ),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), x.dtype),
        interpret=interpret,
    )(x.reshape(nblocks, block))
    return out.reshape(d)
