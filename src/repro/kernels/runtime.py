"""Shared Pallas runtime configuration for the kernels package.

Every kernel wrapper in this package takes ``interpret: bool | None``;
``None`` resolves here so the whole package follows one policy:

* ``REPRO_PALLAS_INTERPRET=1`` (or ``true``/``on``/``yes``) — force
  interpret mode everywhere (CPU correctness runs, CI);
* ``REPRO_PALLAS_INTERPRET=0`` (``false``/``off``/``no``) — force compiled
  kernels (only meaningful on a real TPU backend);
* unset / ``auto`` — interpret off on a real TPU, on everywhere else.

The value is read at trace time: jitted wrappers cache on the *resolved*
``interpret`` only through their first trace with ``interpret=None``, so
set the variable before the first kernel call (conftest/CI do).
"""
from __future__ import annotations

import os

import jax

ENV_VAR = "REPRO_PALLAS_INTERPRET"

_TRUE = ("1", "true", "on", "yes")
_FALSE = ("0", "false", "off", "no")


def default_interpret() -> bool:
    """Resolve the package-wide interpret default (see module docstring)."""
    v = os.environ.get(ENV_VAR, "auto").strip().lower()
    if v in _TRUE:
        return True
    if v in _FALSE:
        return False
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> :func:`default_interpret`, else the explicit value."""
    return default_interpret() if interpret is None else bool(interpret)
