"""RotK (cyclic-partition PermK) apply kernel (Pallas TPU).

Fuses the MARINA-P worker update  w += Q_i(delta)  where
Q_i(delta)_j = n * delta_j * [j mod n == (worker + r) mod n]
into a single VMEM pass: iota-compare mask, scale, accumulate. No index
arrays ever touch HBM — the message is materialized from (r, worker, n)
(zero-byte correlated broadcast, DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret


def _rotk_apply_kernel(w_ref, delta_ref, rot_ref, out_ref, *, n: int, worker: int, block: int):
    i = pl.program_id(0)
    w = w_ref[...]
    delta = delta_ref[...]
    r = rot_ref[0]
    local = jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)
    gidx = i * block + local
    keep = (gidx % n) == ((worker + r) % n)
    out_ref[...] = (w + jnp.where(keep, delta * n, 0.0)).astype(out_ref.dtype)


def rotk_apply(w: jax.Array, delta: jax.Array, rotation: jax.Array, *, n: int,
               worker: int, block: int = 1024,
               interpret: bool | None = None) -> jax.Array:
    """w, delta: [d]; rotation: int32 scalar array. Returns w + Q_i(delta)."""
    interpret = resolve_interpret(interpret)
    d = w.shape[-1]
    assert d % block == 0, (d, block)
    nblocks = d // block
    out = pl.pallas_call(
        functools.partial(_rotk_apply_kernel, n=n, worker=worker, block=block),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), w.dtype),
        interpret=interpret,
    )(w.reshape(nblocks, block), delta.reshape(nblocks, block), rotation.reshape(1))
    return out.reshape(d)
