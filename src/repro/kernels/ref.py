"""Pure-jnp oracles for every kernel in this package (bit-exact semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .randk import hash_uniform  # the hash itself is plain jnp — shared


def block_topk_ref(x: jax.Array, *, k_per_block: int, block: int) -> jax.Array:
    """Exact per-block magnitude top-k (lax.top_k tie-breaking: first index)."""
    d = x.shape[-1]
    assert d % block == 0
    xb = x.reshape(-1, block)
    _, idx = jax.lax.top_k(jnp.abs(xb), k_per_block)
    mask = jnp.zeros_like(xb, dtype=bool)
    mask = jax.vmap(lambda m, i: m.at[i].set(True))(mask, idx)
    return jnp.where(mask, xb, 0).reshape(d)


def bernk_ref(x: jax.Array, *, keep_prob: float, seed: int, worker: int = 0) -> jax.Array:
    idx = jnp.arange(x.shape[-1], dtype=jnp.uint32)
    u = hash_uniform(idx, seed, worker)
    return jnp.where(u < keep_prob, x / keep_prob, 0.0).astype(x.dtype)


def rotk_apply_ref(w, delta, rotation, *, n: int, worker: int):
    idx = jnp.arange(w.shape[-1], dtype=jnp.int32)
    keep = (idx % n) == ((worker + rotation) % n)
    return (w + jnp.where(keep, delta * n, 0.0)).astype(w.dtype)


def pack_bits_ref(values: jax.Array, width: int) -> jax.Array:
    """LSB-first bit packing into uint32 words (wire/bitstream.py layout)."""
    v = values.astype(jnp.uint32)
    n = v.shape[-1]
    nwords = -(-n * width // 32)
    pos = jnp.arange(n, dtype=jnp.int32) * width
    word = pos // 32
    off = (pos % 32).astype(jnp.uint32)
    lo = v << off
    hi = (v >> jnp.uint32(1)) >> (jnp.uint32(31) - off)  # v >> (32-off); off=0 -> 0
    out = jnp.zeros(nwords + 1, jnp.uint32).at[word].add(lo).at[word + 1].add(hi)
    return out[:nwords]


def unpack_bits_ref(words: jax.Array, width: int, count: int) -> jax.Array:
    """Inverse of :func:`pack_bits_ref`."""
    w = jnp.concatenate([words.astype(jnp.uint32), jnp.zeros(1, jnp.uint32)])
    pos = jnp.arange(count, dtype=jnp.int32) * width
    word = pos // 32
    off = (pos % 32).astype(jnp.uint32)
    lo = w[word] >> off
    hi = (w[word + 1] << jnp.uint32(1)) << (jnp.uint32(31) - off)
    mask = jnp.uint32(0xFFFFFFFF if width == 32 else (1 << width) - 1)
    return (lo | hi) & mask


def l1_subgrad_ref(A: jax.Array, x: jax.Array) -> jax.Array:
    y = A.astype(jnp.float32) @ x.astype(jnp.float32)
    s = jnp.where(y >= 0, 1.0, -1.0)
    return A.astype(jnp.float32).T @ s
