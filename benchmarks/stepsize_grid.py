"""Deprecated: folded into ``benchmarks.scenario_matrix``.

The Table 3/6 Polyak-factor sweep is now the stepsize axis of the
scenario matrix (``scenario_matrix.polyak_factor_grid`` /
``scenario_matrix.tune``). This shim keeps the ``stepsize_grid`` suite
name in ``benchmarks/run.py`` and its historical row names stable.
"""
from __future__ import annotations

import warnings

from benchmarks.scenario_matrix import polyak_factor_grid, tune  # noqa: F401


def bench(tracker=None, **kwargs):
    warnings.warn(
        "benchmarks.stepsize_grid is deprecated; use "
        "benchmarks.scenario_matrix.polyak_factor_grid (the stepsize axis "
        "of the scenario matrix)",
        DeprecationWarning,
        stacklevel=2,
    )
    return polyak_factor_grid(tracker=tracker, **kwargs)
