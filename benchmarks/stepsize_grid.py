"""Paper Table 3/6: tuned multiplicative stepsize factors.

Sweeps the factor grid {2^-9 .. 2^7} (reduced stride by default) for each
method and reports the best factor by final suboptimality — the paper's
App. A tuning protocol.
"""
from __future__ import annotations

import time

from repro.core import compressors as C
from repro.core import ef21p, marina_p, problems, stepsizes


def tune(method: str, prob, T=250, factors=None, seed=0):
    d, n = prob.d, prob.n
    k = max(1, d // n)
    p, alpha = k / d, k / d
    factors = factors or [2.0**e for e in range(-7, 6, 2)]
    best = (None, float("inf"))
    for f in factors:
        if method == "ef21p":
            ss = stepsizes.EF21PPolyak(alpha=alpha, f_star=0.0, factor=f)
            h = ef21p.run(prob, C.TopK(k=k), ss, T=T, seed=seed, record_every=T - 1)
        else:
            omega = float(n - 1) if method == "perm" else d / k - 1.0
            ss = stepsizes.MarinaPPolyak(omega=omega, p=p, f_star=0.0, factor=f)
            h = marina_p.run(prob, mode=method, k=k, p=p, stepsize=ss, T=T,
                             seed=seed, record_every=T - 1)
        final = h["f_x"][-1]
        if final < best[1]:
            best = (f, final)
    return best


def bench(tracker=None):
    rows = []
    prob = problems.generate_problem(n=10, d=120, noise_scale=1.0, seed=0)
    for method in ("ef21p", "same", "ind", "perm"):
        t0 = time.time()
        f, final = tune(method, prob)
        dt = (time.time() - t0) * 1e6
        rows.append((f"stepsize_grid/polyak/{method}/best_factor", dt, f))
        rows.append((f"stepsize_grid/polyak/{method}/final_subopt", dt, final))
    return rows
