"""Render the dry-run roofline table (EXPERIMENTS.md §Roofline) from the
JSON records produced by launch/dryrun.py."""
from __future__ import annotations

import glob
import json
import os

DEFAULT_DIR = os.environ.get("DRYRUN_DIR", "runs/dryrun_v2")


def load(dirpath=DEFAULT_DIR, mesh="16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def markdown_table(recs):
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "MODEL_FLOPS | useful ratio | bytes/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        t = r["roofline"]
        mem = r.get("memory_analysis", {})
        dev_bytes = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | {t['memory_s']:.3e} | "
            f"{t['collective_s']:.3e} | {t['dominant'].replace('_s','')} | "
            f"{r['model_flops']:.3e} | "
            f"{(r['useful_flops_ratio'] or 0):.3f} | {dev_bytes:.3e} |"
        )
    return hdr + "\n".join(lines)


def bench(dirpath=DEFAULT_DIR, tracker=None):
    rows = []
    for r in load(dirpath):
        t = r["roofline"]
        rows.append((f"roofline/{r['arch']}/{r['shape']}/bound_s", r["t_compile_s"] * 1e6,
                     t["bound_s"]))
    return rows


if __name__ == "__main__":
    import sys

    d = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_DIR
    print(markdown_table(load(d)))
