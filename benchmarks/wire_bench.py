"""Wire codec benchmark: throughput + measured-vs-analytic parity.

Three sections:

* **throughput** — encode/decode GB/s of the host codecs (sparse, natural,
  dense) and the on-device pack/unpack kernels (interpret mode on CPU);
  rates are measured against the *dense fp32 payload* the codec represents.
* **encode roofline** — GB/s of the fused compressor→bitstream encode
  kernels (kernels/encode.py) per execution mode: the host numpy codec,
  the Pallas kernels in interpret mode, and (on a real TPU backend only)
  compiled. Gated in bench-smoke via BENCH_encode.json, including a
  byte-identity row (fused buffers must equal the host codec's bytes).
* **parity** — runs MARINA-P (same / ind / perm) and EF21-P on the paper's
  L1 workload with ``measure_wire=True`` and reports measured wire
  bits/round next to the analytic CommLedger (value_bits matched to fp32).
  The three broadcast modes must agree within 5% (acceptance criterion);
  ``--smoke`` shrinks sizes/rounds and exits non-zero on violation (CI).

Usage: PYTHONPATH=src python benchmarks/wire_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import wire
from repro.core import compressors as C
from repro.core import ef21p, marina_p, problems, stepsizes
from repro.kernels import ops


def _time(fn, iters=5):
    fn()  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def throughput_rows(smoke: bool):
    d = 1 << 16 if smoke else 1 << 20
    rng = np.random.default_rng(0)
    dense = rng.standard_normal(d).astype(np.float32)
    sparse_vec = np.where(rng.random(d) < 1 / 16, dense, 0.0).astype(np.float32)
    nat = np.asarray(
        C.NaturalCompression()(jax.random.PRNGKey(0), jnp.asarray(dense))
    )
    payload_gb = dense.nbytes / 1e9

    rows = []
    for name, enc_fn in (
        ("sparse/encode", lambda: wire.encode_sparse(sparse_vec)),
        ("natural/encode", lambda: wire.encode_natural(nat)),
        ("dense/encode", lambda: wire.encode_dense(dense)),
    ):
        dt = _time(enc_fn)
        buf = enc_fn()
        rows.append((name, payload_gb / dt, len(buf)))
        dec = lambda b=buf: wire.decode(b)
        rows.append((name.replace("encode", "decode"), payload_gb / _time(dec), len(buf)))

    width = wire.index_width(d)
    idx = np.nonzero(sparse_vec)[0].astype(np.uint32)
    vals_j = jnp.asarray(idx)
    pack = lambda: ops.pack_bits(vals_j, width=width)
    packed = pack()
    unpack = lambda: ops.unpack_bits(packed, width=width, count=idx.size)
    pack_gb = idx.size * 4 / 1e9
    rows.append((f"kernels/pack_bits[w={width}]", pack_gb / _time(pack), int(packed.size * 4)))
    rows.append((f"kernels/unpack_bits[w={width}]", pack_gb / _time(unpack), int(idx.size * 4)))
    return rows


def _encode_inputs(d: int):
    rng = np.random.default_rng(0)
    dense = rng.standard_normal(d).astype(np.float32)
    sparse_vec = np.where(rng.random(d) < 1 / 16, dense, 0.0).astype(np.float32)
    return dense, sparse_vec, jnp.asarray(dense), jnp.asarray(sparse_vec)


def _encode_cases(kenc, dj, sj, interpret):
    return (
        ("sparse", lambda: kenc.sparse_encode(sj, interpret=interpret)),
        ("topk", lambda: kenc.topk_encode(dj, k_per_block=64, block=1024,
                                          interpret=interpret)),
        ("mask", lambda: kenc.mask_encode(dj, keep_prob=1 / 16, seed=7,
                                          interpret=interpret)),
        ("dense", lambda: kenc.dense_encode(dj, interpret=interpret)),
    )


def encode_roofline_rows(smoke: bool):
    """GB/s roofline of the fused encode paths (kernels/encode.py).

    One row per (mode, path): ``host`` is the numpy codec, ``interpret``
    runs the Pallas bodies as traced Python (the CPU-container floor), and
    ``compiled`` appears only on a real TPU backend — Pallas compilation
    is unavailable off-TPU, so its absence on CPU is the roofline's
    honest gap, not a silent fallback. Rates are against the dense fp32
    payload the message represents.
    """
    from repro.kernels import encode as kenc

    d = 1 << 14 if smoke else 1 << 16
    dense, sparse_vec, dj, sj = _encode_inputs(d)
    payload_gb = dense.nbytes / 1e9
    rows = []
    for name, fn in (
        ("sparse", lambda: wire.encode_sparse(sparse_vec)),
        ("dense", lambda: wire.encode_dense(dense)),
    ):
        dt = _time(fn, iters=3)
        rows.append((f"encode/host/{name}", payload_gb / dt, len(fn())))
    modes = [("interpret", True)]
    if jax.default_backend() == "tpu":
        modes.append(("compiled", False))
    for label, interp in modes:
        for name, fn in _encode_cases(kenc, dj, sj, interp):
            dt = _time(fn, iters=3)
            rows.append((f"encode/{label}/{name}", payload_gb / dt, len(fn())))
    return rows


def bench_encode(tracker=None):
    """benchmarks.run adapter for the fused-encode suite (BENCH_encode.json).

    Rows are (name, us_per_call, derived GB/s) for the host codec and each
    fused device path at the bench-smoke size, plus ``encode/byte_identical``
    whose derived value is 1.0 iff every fused buffer equals the host
    codec's bytes on this run — gated ``eq`` so bench-smoke fails on any
    stream divergence, not just a perf regression.
    """
    from repro.kernels import encode as kenc
    from repro.kernels import ops

    d = 1 << 14
    dense, sparse_vec, dj, sj = _encode_inputs(d)
    payload_gb = dense.nbytes / 1e9
    rows = []
    cases = [("encode/host_sparse", lambda: wire.encode_sparse(sparse_vec)),
             ("encode/host_dense", lambda: wire.encode_dense(dense))]
    cases += [(f"encode/device_{name}", fn)
              for name, fn in _encode_cases(kenc, dj, sj, None)]
    for name, fn in cases:
        dt = _time(fn, iters=3)
        rows.append((name, dt * 1e6, round(payload_gb / dt, 4)))
    t0 = time.perf_counter()
    same = (
        kenc.sparse_encode(sj) == wire.encode_sparse(sparse_vec)
        and kenc.dense_encode(dj) == wire.encode_dense(dense)
        and kenc.topk_encode(dj, k_per_block=64, block=1024)
        == wire.encode_sparse(
            np.asarray(ops.block_topk(dj, k_per_block=64, block=1024)))
        and kenc.mask_encode(dj, keep_prob=1 / 16, seed=7)
        == wire.encode_sparse(
            np.asarray(ops.bernk(dj, keep_prob=1 / 16, seed=7)))
    )
    rows.append(("encode/byte_identical", (time.perf_counter() - t0) * 1e6,
                 1.0 if same else 0.0))
    return rows


def parity_rows(smoke: bool):
    d, n = (256, 4) if smoke else (1024, 4)
    T = 30 if smoke else 200
    prob = problems.generate_problem(n=n, d=d, noise_scale=1.0, seed=0)
    ss = stepsizes.Constant(gamma=0.02)
    rows = []
    for mode in ("same", "ind", "perm"):
        h = marina_p.run(
            prob, mode=mode, k=d // n, p=1.0 / n, stepsize=ss, T=T, measure_wire=True
        )
        a, w = h["wire_model_ledger"].s2w_bits, h["wire_bits_total"]
        rows.append((f"marina_p/{mode}", a / T, w / T, 100.0 * (w - a) / a))
    h = ef21p.run(
        prob, C.BlockTopK(k_per_block=16, block=128), ss, T=T, measure_wire=True
    )
    a, w = h["wire_model_ledger"].s2w_bits, h["wire_bits_total"]
    rows.append(("ef21p/block_topk", a / T, w / T, 100.0 * (w - a) / a))
    return rows


def bench(tracker=None):
    """benchmarks.run harness adapter: (name, us_per_call, derived) rows.

    ``derived`` is the codec throughput in GB/s against the dense fp32
    payload (numeric, so BENCH artifacts can gate on it). With a tracker,
    also logs the smoke-size measured-vs-analytic parity per mode.
    """
    d = 1 << 16
    rng = np.random.default_rng(0)
    dense = rng.standard_normal(d).astype(np.float32)
    sparse_vec = np.where(rng.random(d) < 1 / 16, dense, 0.0).astype(np.float32)
    rows = []
    for name, fn in (
        ("wire/sparse_encode", lambda: wire.encode_sparse(sparse_vec)),
        ("wire/sparse_decode", lambda b=wire.encode_sparse(sparse_vec): wire.decode(b)),
        ("wire/dense_encode", lambda: wire.encode_dense(dense)),
    ):
        dt = _time(fn)
        rows.append((name, dt * 1e6, round(dense.nbytes / 1e9 / dt, 3)))
    if tracker is not None:
        for name, analytic, measured, pct in parity_rows(smoke=True):
            tracker.log({f"parity/{name}": {
                "bits_per_round_analytic": analytic,
                "bits_per_round_wire": measured,
                "diff_pct": pct}})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small sizes + assert parity (CI)")
    args = ap.parse_args(argv)

    print("== codec throughput (dense-payload GB/s) ==")
    for name, gbs, nbytes in throughput_rows(args.smoke):
        print(f"{name:32s} {gbs:8.3f} GB/s   ({nbytes} wire bytes)")

    print("\n== fused encode roofline (dense-payload GB/s) ==")
    for name, gbs, nbytes in encode_roofline_rows(args.smoke):
        print(f"{name:32s} {gbs:8.3f} GB/s   ({nbytes} wire bytes)")

    print("\n== measured vs analytic bits/round ==")
    failures = []
    for name, analytic, measured, pct in parity_rows(args.smoke):
        print(f"{name:24s} analytic={analytic:12.1f}  wire={measured:12.1f}  diff={pct:+.2f}%")
        if name.startswith("marina_p/") and abs(pct) > 5.0:
            failures.append((name, pct))
    if failures:
        print(f"PARITY FAILURES: {failures}", file=sys.stderr)
        return 1
    print("parity OK (marina_p modes within 5%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
