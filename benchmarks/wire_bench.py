"""Wire codec benchmark: throughput + measured-vs-analytic parity.

Two sections:

* **throughput** — encode/decode GB/s of the host codecs (sparse, natural,
  dense) and the on-device pack/unpack kernels (interpret mode on CPU);
  rates are measured against the *dense fp32 payload* the codec represents.
* **parity** — runs MARINA-P (same / ind / perm) and EF21-P on the paper's
  L1 workload with ``measure_wire=True`` and reports measured wire
  bits/round next to the analytic CommLedger (value_bits matched to fp32).
  The three broadcast modes must agree within 5% (acceptance criterion);
  ``--smoke`` shrinks sizes/rounds and exits non-zero on violation (CI).

Usage: PYTHONPATH=src python benchmarks/wire_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import wire
from repro.core import compressors as C
from repro.core import ef21p, marina_p, problems, stepsizes
from repro.kernels import ops


def _time(fn, iters=5):
    fn()  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def throughput_rows(smoke: bool):
    d = 1 << 16 if smoke else 1 << 20
    rng = np.random.default_rng(0)
    dense = rng.standard_normal(d).astype(np.float32)
    sparse_vec = np.where(rng.random(d) < 1 / 16, dense, 0.0).astype(np.float32)
    nat = np.asarray(
        C.NaturalCompression()(jax.random.PRNGKey(0), jnp.asarray(dense))
    )
    payload_gb = dense.nbytes / 1e9

    rows = []
    for name, enc_fn in (
        ("sparse/encode", lambda: wire.encode_sparse(sparse_vec)),
        ("natural/encode", lambda: wire.encode_natural(nat)),
        ("dense/encode", lambda: wire.encode_dense(dense)),
    ):
        dt = _time(enc_fn)
        buf = enc_fn()
        rows.append((name, payload_gb / dt, len(buf)))
        dec = lambda b=buf: wire.decode(b)
        rows.append((name.replace("encode", "decode"), payload_gb / _time(dec), len(buf)))

    width = wire.index_width(d)
    idx = np.nonzero(sparse_vec)[0].astype(np.uint32)
    vals_j = jnp.asarray(idx)
    pack = lambda: ops.pack_bits(vals_j, width=width)
    packed = pack()
    unpack = lambda: ops.unpack_bits(packed, width=width, count=idx.size)
    pack_gb = idx.size * 4 / 1e9
    rows.append((f"kernels/pack_bits[w={width}]", pack_gb / _time(pack), int(packed.size * 4)))
    rows.append((f"kernels/unpack_bits[w={width}]", pack_gb / _time(unpack), int(idx.size * 4)))
    return rows


def parity_rows(smoke: bool):
    d, n = (256, 4) if smoke else (1024, 4)
    T = 30 if smoke else 200
    prob = problems.generate_problem(n=n, d=d, noise_scale=1.0, seed=0)
    ss = stepsizes.Constant(gamma=0.02)
    rows = []
    for mode in ("same", "ind", "perm"):
        h = marina_p.run(
            prob, mode=mode, k=d // n, p=1.0 / n, stepsize=ss, T=T, measure_wire=True
        )
        a, w = h["wire_model_ledger"].s2w_bits, h["wire_bits_total"]
        rows.append((f"marina_p/{mode}", a / T, w / T, 100.0 * (w - a) / a))
    h = ef21p.run(
        prob, C.BlockTopK(k_per_block=16, block=128), ss, T=T, measure_wire=True
    )
    a, w = h["wire_model_ledger"].s2w_bits, h["wire_bits_total"]
    rows.append(("ef21p/block_topk", a / T, w / T, 100.0 * (w - a) / a))
    return rows


def bench(tracker=None):
    """benchmarks.run harness adapter: (name, us_per_call, derived) rows.

    ``derived`` is the codec throughput in GB/s against the dense fp32
    payload (numeric, so BENCH artifacts can gate on it). With a tracker,
    also logs the smoke-size measured-vs-analytic parity per mode.
    """
    d = 1 << 16
    rng = np.random.default_rng(0)
    dense = rng.standard_normal(d).astype(np.float32)
    sparse_vec = np.where(rng.random(d) < 1 / 16, dense, 0.0).astype(np.float32)
    rows = []
    for name, fn in (
        ("wire/sparse_encode", lambda: wire.encode_sparse(sparse_vec)),
        ("wire/sparse_decode", lambda b=wire.encode_sparse(sparse_vec): wire.decode(b)),
        ("wire/dense_encode", lambda: wire.encode_dense(dense)),
    ):
        dt = _time(fn)
        rows.append((name, dt * 1e6, round(dense.nbytes / 1e9 / dt, 3)))
    if tracker is not None:
        for name, analytic, measured, pct in parity_rows(smoke=True):
            tracker.log({f"parity/{name}": {
                "bits_per_round_analytic": analytic,
                "bits_per_round_wire": measured,
                "diff_pct": pct}})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small sizes + assert parity (CI)")
    args = ap.parse_args(argv)

    print("== codec throughput (dense-payload GB/s) ==")
    for name, gbs, nbytes in throughput_rows(args.smoke):
        print(f"{name:32s} {gbs:8.3f} GB/s   ({nbytes} wire bytes)")

    print("\n== measured vs analytic bits/round ==")
    failures = []
    for name, analytic, measured, pct in parity_rows(args.smoke):
        print(f"{name:24s} analytic={analytic:12.1f}  wire={measured:12.1f}  diff={pct:+.2f}%")
        if name.startswith("marina_p/") and abs(pct) > 5.0:
            failures.append((name, pct))
    if failures:
        print(f"PARITY FAILURES: {failures}", file=sys.stderr)
        return 1
    print("parity OK (marina_p modes within 5%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
