"""Transport benchmark: framing cost + degraded-mode training overhead.

Two sections (DESIGN.md §8.6):

* **framing** — frame encode/decode and CRC32C throughput (the per-message
  host cost the transport adds on top of the wire codecs);
* **chaos** — MARINA-P on the paper's L1 workload, clean vs through a
  degraded fleet (the acceptance fault model: 10% drop, 2% corruption,
  reorder window 4). Reports goodput, retry/resync counters, and
  ``rounds_ratio`` = faulty/clean rounds to the same loss target — the
  end-to-end price of the fault model. Deterministic (seeded injectors +
  seeded algorithm), so CI gates on goodput and rounds_ratio.

Usage: PYTHONPATH=src python benchmarks/transport_bench.py
       (or via the harness: python -m benchmarks.run transport)
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import problems, stepsizes, marina_p
from repro.transport import FaultSpec, Fleet, FrameType, crc32c, decode_frame, encode_frame

CHAOS_SPEC = FaultSpec(drop=0.10, corrupt=0.02, reorder=0.10, reorder_window=4, seed=7)


def _time(fn, iters=5):
    fn()  # warm-up
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def framing_rows():
    payload = np.random.default_rng(0).integers(0, 256, 1 << 16, dtype=np.uint8).tobytes()
    buf = encode_frame(FrameType.DATA, 1, payload)
    gb = len(payload) / 1e9
    rows = []
    dt = _time(lambda: encode_frame(FrameType.DATA, 1, payload))
    rows.append(("transport/frame_encode", dt * 1e6, round(gb / dt, 4)))
    dt = _time(lambda: decode_frame(buf))
    rows.append(("transport/frame_decode", dt * 1e6, round(gb / dt, 4)))
    dt = _time(lambda: crc32c(payload))
    rows.append(("transport/crc32c", dt * 1e6, round(gb / dt, 4)))
    return rows


def chaos_metrics():
    """Clean vs degraded MARINA-P; returns the gateable scalars."""
    prob = problems.generate_problem(n=8, d=64, noise_scale=1.0, seed=0)
    k = prob.d // prob.n
    p = k / prob.d
    ss = stepsizes.MarinaPPolyak(omega=prob.n - 1, p=p, f_star=prob.f_star)

    clean = marina_p.run(prob, mode="perm", k=k, p=p, stepsize=ss, T=120, seed=1)
    target = 0.25 * clean["f_x"][0]
    r_clean = next(t for t, f in zip(clean["t"], clean["f_x"]) if f < target)

    fleet = Fleet.make(prob.n, CHAOS_SPEC, timeout=2, max_retries=1)
    t0 = time.perf_counter()
    h = marina_p.run(prob, mode="perm", k=k, p=p, stepsize=ss, T=120, seed=1,
                     transport=fleet)
    chaos_s = time.perf_counter() - t0
    r_faulty = next(
        (t for t, f in zip(h["t"], h["f_x"]) if f < target), h["t"][-1]
    )
    tr = h["transport"]
    return {
        "transport/goodput": round(tr["transport/goodput"], 4),
        "transport/rounds_ratio": round(r_faulty / max(r_clean, 1), 4),
        "transport/retries": tr["transport/retries"],
        "transport/resyncs": tr["transport/resyncs"],
        "transport/forced_syncs": tr["transport/forced_syncs"],
        "transport/recovery_ticks_mean": round(tr["transport/recovery_ticks_mean"], 3),
        "transport/chaos_run_s": round(chaos_s, 3),
    }


def bench(tracker=None):
    """benchmarks.run harness adapter: (name, us_per_call, derived) rows.

    Framing rows carry GB/s deriveds; the chaos-run scalars (goodput,
    rounds_ratio, counters) go through ``tracker`` so the BENCH artifact
    can gate on them.
    """
    rows = framing_rows()
    metrics = chaos_metrics()
    if tracker is not None:
        tracker.log(metrics)
    return rows


def main():
    print("== framing throughput (GB/s) ==")
    for name, us, gbs in framing_rows():
        print(f"{name:28s} {us:10.1f} us/call   {gbs:8.4f} GB/s")
    print("\n== chaos run (10% drop, 2% corrupt, reorder w=4) ==")
    for name, v in chaos_metrics().items():
        print(f"{name:32s} {v}")


if __name__ == "__main__":
    main()
