"""Diff fresh ``BENCH_*.json`` artifacts against committed baselines.

The baseline documents are self-describing: each carries a ``gates`` list
({pattern, field, direction, rtol}) written by benchmarks/run.py. For
every baseline metric matched by a gate, the fresh artifact must contain
the same metric/field and satisfy the tolerance:

    lower   fresh <= base * (1 + rtol)        (latency-style: lower is better)
    higher  fresh >= base * (1 - rtol)        (throughput-style)
    eq      |fresh - base| <= rtol * max(|base|, eps)

Exactly-at-threshold passes. A gated metric missing from the fresh run is
a regression (a benchmark silently disappearing must not pass CI). A fresh
artifact with no committed baseline fails unless ``--ignore-missing`` —
the flag exists so brand-new suites can land before their first baseline.

Usage:
    python -m benchmarks.bench_diff --fresh runs/bench \
        --baseline benchmarks/baselines [--suite wire --suite kernels]

Exit code 0 = no regressions; 1 = regression / missing artifact.
Baseline update workflow: DESIGN.md §7.4.
"""
from __future__ import annotations

import argparse
import fnmatch
import glob
import os
import sys
from typing import Any, Dict, List, Mapping, Optional, Tuple

_EPS = 1e-12


def _check(base: float, fresh: float, direction: str, rtol: float) -> Optional[str]:
    """None if within tolerance, else a human-readable violation."""
    if direction == "lower":
        limit = base * (1.0 + rtol)
        if fresh > limit:
            return f"{fresh:.6g} > {limit:.6g} (baseline {base:.6g}, rtol {rtol})"
    elif direction == "higher":
        limit = base * (1.0 - rtol)
        if fresh < limit:
            return f"{fresh:.6g} < {limit:.6g} (baseline {base:.6g}, rtol {rtol})"
    elif direction == "eq":
        tol = rtol * max(abs(base), _EPS)
        if abs(fresh - base) > tol:
            return f"|{fresh:.6g} - {base:.6g}| > {tol:.6g} (rtol {rtol})"
    else:
        return f"unknown direction {direction!r}"
    return None


def diff_docs(baseline: Mapping[str, Any], fresh: Mapping[str, Any]) -> Tuple[List[str], List[str]]:
    """Compare one suite's fresh doc against its baseline.

    Returns (failures, checked) — ``checked`` lists every gated
    comparison that ran, for the report.
    """
    failures: List[str] = []
    checked: List[str] = []
    base_metrics: Mapping[str, Any] = baseline.get("metrics", {})
    fresh_metrics: Mapping[str, Any] = fresh.get("metrics", {})
    for gate in baseline.get("gates", []):
        pattern, field = gate["pattern"], gate["field"]
        for name, base_entry in sorted(base_metrics.items()):
            if not fnmatch.fnmatch(name, pattern) or field not in base_entry:
                continue
            label = f"{name}:{field}"
            fresh_entry = fresh_metrics.get(name)
            if fresh_entry is None or field not in fresh_entry:
                failures.append(f"{label}: missing from fresh run")
                continue
            checked.append(label)
            violation = _check(
                float(base_entry[field]), float(fresh_entry[field]),
                gate["direction"], float(gate["rtol"]),
            )
            if violation:
                failures.append(f"{label}: {violation}")
    return failures, checked


def diff_dirs(
    baseline_dir: str,
    fresh_dir: str,
    *,
    suites: Optional[List[str]] = None,
    ignore_missing: bool = False,
) -> Tuple[List[str], List[str]]:
    """Diff every BENCH_<suite>.json present in either directory."""
    from repro import obs

    def suites_in(dirpath: str) -> Dict[str, str]:
        out = {}
        for path in glob.glob(os.path.join(dirpath, "BENCH_*.json")):
            out[os.path.basename(path)[len("BENCH_"):-len(".json")]] = path
        return out

    base_files, fresh_files = suites_in(baseline_dir), suites_in(fresh_dir)
    names = suites or sorted(set(base_files) | set(fresh_files))
    failures: List[str] = []
    report: List[str] = []
    for suite in names:
        bpath, fpath = base_files.get(suite), fresh_files.get(suite)
        if fpath is None:
            failures.append(f"[{suite}] fresh BENCH_{suite}.json missing from {fresh_dir}")
            continue
        if bpath is None:
            msg = f"[{suite}] no committed baseline in {baseline_dir}"
            (report if ignore_missing else failures).append(
                msg + (" (ignored)" if ignore_missing else "")
            )
            continue
        base_doc, fresh_doc = obs.load(bpath), obs.load(fpath)
        for doc, path in ((base_doc, bpath), (fresh_doc, fpath)):
            errors = obs.validate(doc)
            if errors:
                failures.append(f"[{suite}] {path} schema-invalid: {errors[0]}")
        fails, checked = diff_docs(base_doc, fresh_doc)
        failures.extend(f"[{suite}] {f}" for f in fails)
        report.append(f"[{suite}] {len(checked)} gated metrics checked, "
                      f"{len(fails)} regressions")
    return failures, report


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", default="benchmarks/baselines",
                    help="directory of committed BENCH_*.json baselines")
    ap.add_argument("--fresh", default="runs/bench",
                    help="directory of freshly produced BENCH_*.json")
    ap.add_argument("--suite", action="append", default=None,
                    help="restrict to these suites (repeatable)")
    ap.add_argument("--ignore-missing", action="store_true",
                    help="pass when a fresh suite has no committed baseline")
    args = ap.parse_args(argv)

    failures, report = diff_dirs(
        args.baseline, args.fresh, suites=args.suite, ignore_missing=args.ignore_missing
    )
    for line in report:
        print(line)
    if failures:
        print(f"\nREGRESSIONS ({len(failures)}):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench_diff: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
