"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  fig1_convergence   Fig. 1/7   EF21-P vs MARINA-P (same/ind/perm), const/Polyak
  table2_sigma       Table 2    sigma_A per (n, noise scale), paper sizes
  stepsize_grid      Table 3/6  tuned Polyak factor grid
  comm_complexity    Cor. 1/2   rounds-to-eps vs closed-form complexity
  kernel_bench       —          Pallas kernel (interpret) microbenchmarks
  wire_bench         DESIGN §3  wire codec throughput (also a standalone CLI
                                with measured-vs-analytic parity checks)
  roofline_report    §Roofline  dominant-term bound per (arch x shape) dry-run

Select subsets: ``python -m benchmarks.run fig1 table2 ...`` (default: all
except roofline_report when no dry-run records exist).
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    from benchmarks import (
        comm_complexity,
        fig1_convergence,
        kernel_bench,
        roofline_report,
        stepsize_grid,
        table2_sigma,
        wire_bench,
    )

    suites = {
        "fig1": fig1_convergence.bench,
        "table2": table2_sigma.bench,
        "stepsize_grid": stepsize_grid.bench,
        "comm_complexity": comm_complexity.bench,
        "kernels": kernel_bench.bench,
        "wire": wire_bench.bench,
        "roofline": roofline_report.bench,
    }
    selected = [a for a in sys.argv[1:] if a in suites]
    if not selected:
        selected = ["fig1", "table2", "stepsize_grid", "comm_complexity", "kernels", "wire"]
        if os.path.isdir(roofline_report.DEFAULT_DIR) and os.listdir(roofline_report.DEFAULT_DIR):
            selected.append("roofline")
    print("name,us_per_call,derived")
    for key in selected:
        try:
            for name, us, derived in suites[key]():
                print(f"{name},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{key}/FAILED,0,nan")


if __name__ == "__main__":
    main()
