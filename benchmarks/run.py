"""Benchmark harness — one module per paper table/figure.

Each suite streams ``(name, us_per_call, derived)`` rows through a
composite tracker (repro.obs): stdout CSV (the historical format), an
optional JSONL event log, and a schema-versioned ``BENCH_<suite>.json``
perf artifact with provenance (git rev, jax version, device kind, seed)
and regression gates for ``benchmarks/bench_diff.py``. Modules:

  fig1_convergence   Fig. 1/7   EF21-P vs MARINA-P (same/ind/perm), const/Polyak
  table2_sigma       Table 2    sigma_A per (n, noise scale), paper sizes
  stepsize_grid      Table 3/6  tuned Polyak factor grid
  comm_complexity    Cor. 1/2   rounds-to-eps vs closed-form complexity
  kernel_bench       —          Pallas kernel (interpret) microbenchmarks
  wire_bench         DESIGN §3  wire codec throughput (also a standalone CLI
                                with measured-vs-analytic parity checks);
                                also provides the ``encode`` suite — fused
                                on-device encode roofline + byte-identity
                                gate (DESIGN §11)
  transport_bench    DESIGN §8  frame/CRC throughput + clean-vs-degraded
                                MARINA-P chaos run (goodput, rounds_ratio)
  serve_bench        DESIGN §10 DecodeEngine prefill/decode span p50/p99
                                latency + tokens/s (smoke config)
  scenario_matrix    DESIGN §9  (algorithm x stepsize x client-mix) fleet
                                cells, one BENCH_scenario_<cell>.json each
  roofline_report    §Roofline  dominant-term bound per (arch x shape) dry-run

Select subsets: ``python -m benchmarks.run fig1 table2 ...`` (default: all
except roofline_report when no dry-run records exist). A suite that raises
prints its traceback, emits a ``<suite>/FAILED`` row, skips its BENCH
artifact, and the run exits non-zero — CI cannot green-light a broken
benchmark.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

# Regression gates baked into each suite's BENCH artifact (self-describing
# baselines — bench_diff reads them back). Timing tolerances are loose
# (5x) because CI machines vary; deterministic deriveds are tight.
_TIME = {"pattern": "*", "field": "us_per_call", "direction": "lower", "rtol": 4.0}
GATES = {
    "kernels": [_TIME],
    "wire": [
        _TIME,
        # derived value = codec throughput in GB/s (higher is better)
        {"pattern": "wire/*", "field": "value", "direction": "higher", "rtol": 0.9},
    ],
    "encode": [
        _TIME,
        # host-codec GB/s floor (device interpret rows are covered by _TIME;
        # their wall-clock varies too much across CI machines for a
        # throughput gate)
        {"pattern": "encode/host_*", "field": "value", "direction": "higher", "rtol": 0.9},
        # fused streams must equal the host codec's bytes — a correctness
        # gate riding the perf artifact (1.0 = identical, exact match)
        {"pattern": "encode/byte_identical", "field": "value", "direction": "eq", "rtol": 0.0},
    ],
    "table2": [
        # sigma_A is deterministic for a fixed seed/platform
        {"pattern": "table2/*", "field": "value", "direction": "eq", "rtol": 0.05},
    ],
    "fig1": [_TIME],
    "stepsize_grid": [_TIME],
    "comm_complexity": [_TIME],
    "roofline": [],
    "transport": [
        _TIME,
        # chaos-run quality: payload bytes delivered / wire bytes sent
        {"pattern": "transport/goodput", "field": "value", "direction": "higher", "rtol": 0.3},
        # degraded rounds-to-target / clean rounds-to-target
        {"pattern": "transport/rounds_ratio", "field": "value", "direction": "lower", "rtol": 0.5},
    ],
    "serve": [
        _TIME,
        # span-derived request latency percentiles (ms, lower is better);
        # slack matches _TIME — CI machines vary widely on wall-clock
        {"pattern": "serve/*_ms", "field": "value", "direction": "lower", "rtol": 4.0},
        # decode throughput from the same spans (higher is better)
        {"pattern": "serve/tokens_per_s", "field": "value", "direction": "higher", "rtol": 0.8},
    ],
    "scenario": [
        _TIME,
        # convergence speed per matrix cell (deterministic for a fixed seed;
        # slack covers cross-platform float drift)
        {"pattern": "scenario/*/rounds_to_target", "field": "value", "direction": "lower", "rtol": 0.5},
        # analytic downlink cost must not creep up
        {"pattern": "scenario/*/s2w_bits", "field": "value", "direction": "lower", "rtol": 0.5},
        # delivered / sent participant messages under the mix's fault model
        {"pattern": "scenario/*/goodput", "field": "value", "direction": "higher", "rtol": 0.3},
    ],
}


def main(argv=None) -> int:
    from benchmarks import (
        comm_complexity,
        fig1_convergence,
        kernel_bench,
        roofline_report,
        scenario_matrix,
        serve_bench,
        stepsize_grid,
        table2_sigma,
        transport_bench,
        wire_bench,
    )
    from repro import obs

    suites = {
        "fig1": fig1_convergence.bench,
        "table2": table2_sigma.bench,
        "stepsize_grid": stepsize_grid.bench,
        "comm_complexity": comm_complexity.bench,
        "kernels": kernel_bench.bench,
        "wire": wire_bench.bench,
        "encode": wire_bench.bench_encode,
        "roofline": roofline_report.bench,
        "transport": transport_bench.bench,
        "serve": serve_bench.bench,
        # per-cell artifacts land next to the suite artifact (args.out is
        # bound at call time, after parsing)
        "scenario": lambda tracker=None: scenario_matrix.bench(
            tracker=tracker, out_dir=args.out),
    }
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("suites", nargs="*",
                    help=f"subset of {sorted(suites)} (default: all with available inputs)")
    ap.add_argument("--out", default=os.environ.get("REPRO_BENCH_DIR", "runs/bench"),
                    help="directory for BENCH_<suite>.json artifacts")
    ap.add_argument("--jsonl", default=None,
                    help="also append every event to this JSONL log")
    ap.add_argument("--seed", type=int, default=0,
                    help="recorded in BENCH env provenance")
    args = ap.parse_args(argv)

    unknown = [s for s in args.suites if s not in suites]
    if unknown:
        ap.error(f"unknown suites {unknown}; choose from {sorted(suites)}")
    selected = list(args.suites)
    if not selected:
        selected = ["fig1", "table2", "stepsize_grid", "comm_complexity", "kernels",
                    "wire", "encode", "transport", "serve", "scenario"]
        if os.path.isdir(roofline_report.DEFAULT_DIR) and os.listdir(roofline_report.DEFAULT_DIR):
            selected.append("roofline")

    jsonl = obs.JsonlTracker(args.jsonl) if args.jsonl else None
    print("name,us_per_call,derived")
    failures = []
    for key in selected:
        sink = obs.BenchJsonSink(key, args.out, seed=args.seed, gates=GATES.get(key, []))
        tracker = obs.CompositeTracker(obs.CsvStdoutTracker(), sink, jsonl)
        try:
            with tracker.time_block(f"{key}/suite"):
                rows = suites[key](tracker=tracker)
            for name, us, derived in rows:
                tracker.log_row(name, us, derived)
            sink.finish()
        except Exception:  # noqa: BLE001 - report, then fail the run
            traceback.print_exc()
            print(f"{key}/FAILED,0,nan")
            failures.append(key)
    if jsonl is not None:
        jsonl.finish()
    if failures:
        print(f"FAILED suites: {','.join(failures)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
