"""Scenario matrix: (algorithm x stepsize scheme x client mix) cells.

Every cell drives ``repro.fleet.fleet_run`` over a declarative client
population and writes one schema-versioned ``BENCH_scenario_<cell>.json``
artifact (repro.obs sink fan-out) reporting rounds-to-target, downlink
bits (analytic 64-bit model + measured wire bytes) and
participation/goodput stats. The aggregate ``scenario`` suite artifact
carries one gated row set per cell for ``benchmarks/bench_diff.py``.

The old ``benchmarks/stepsize_grid.py`` Polyak-factor sweep (paper Table
3/6) is folded in here as :func:`polyak_factor_grid` — the stepsize axis
of the matrix — and ``stepsize_grid`` remains a deprecation shim so
``benchmarks/run.py`` suite names stay stable.

CLI:  PYTHONPATH=src python -m benchmarks.scenario_matrix [--full] [--out DIR]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_OUT = os.environ.get("REPRO_BENCH_DIR", "runs/bench")

# mix -> the sampler that exercises its heterogeneity axis
MIX_SAMPLER = {
    "uniform": "uniform",
    "two_tier": "weighted",
    "two_tier_diurnal": "availability",
    "flaky_mobile": "deadline:2.5",
}

# default 2 x 2 x 2 matrix (ISSUE acceptance: >= 8 cells); --full widens
DEFAULT_CELLS: List[Tuple[str, str, str]] = [
    (alg, scheme, mix)
    for alg in ("marina_p", "ef21p")
    for scheme in ("constant", "polyak")
    for mix in ("uniform", "two_tier_diurnal")
]
FULL_CELLS: List[Tuple[str, str, str]] = [
    (alg, scheme, mix)
    for alg in ("marina_p", "ef21p")
    for scheme in ("constant", "decreasing", "polyak")
    for mix in ("uniform", "two_tier", "two_tier_diurnal", "flaky_mobile")
]


def cell_id(alg: str, scheme: str, mix: str) -> str:
    return f"{alg}-{scheme}-{mix}".replace(":", "")


def _build_stepsize(alg: str, scheme: str, prob, k: int, p: float, n_eff: int, T: int):
    from repro.core import stepsizes

    alpha = k / prob.d
    omega = float(n_eff - 1)  # perm-mode broadcast over the cohort slots
    if scheme == "polyak":
        if alg == "ef21p":
            return stepsizes.EF21PPolyak(alpha=alpha, f_star=0.0)
        return stepsizes.MarinaPPolyak(omega=omega, p=p, f_star=0.0)
    L0_bar, L0_tilde = prob.lipschitz_estimates()
    V0 = prob.R0_sq
    if scheme == "constant":
        if alg == "ef21p":
            return stepsizes.Constant(
                gamma=stepsizes.ef21p_optimal_constant(V0, L0_bar, alpha, T))
        return stepsizes.Constant(
            gamma=stepsizes.marina_p_optimal_constant(V0, L0_bar, L0_tilde, omega, p, T))
    if scheme == "decreasing":
        if alg == "ef21p":
            return stepsizes.Decreasing(
                gamma0=stepsizes.ef21p_optimal_decreasing_gamma0(V0, L0_bar, alpha, T))
        return stepsizes.Decreasing(
            gamma0=stepsizes.marina_p_optimal_decreasing_gamma0(
                V0, L0_bar, L0_tilde, omega, p, T))
    raise ValueError(f"unknown stepsize scheme: {scheme!r}")


def run_cell(
    alg: str,
    scheme: str,
    mix: str,
    *,
    population: int = 4096,
    cohort: int = 16,
    d: int = 64,
    T: int = 120,
    target_frac: float = 0.3,
    seed: int = 0,
    measure_wire: bool = True,
    tracker=None,
) -> Dict[str, float]:
    """One matrix cell -> flat metrics dict (the per-cell artifact body)."""
    from repro.fleet import FleetL1Problem, fleet_run, make_fleet, make_sampler

    spec = make_fleet(mix, population, seed=seed)
    prob = FleetL1Problem(spec, d=d)
    sampler = make_sampler(MIX_SAMPLER[mix], spec, cohort, seed=seed)
    k = max(1, d // cohort)
    p = k / d
    stepsize = _build_stepsize(alg, scheme, prob, k, p, cohort, T)
    # rounds-to-target on the fixed eval cohort: reach target_frac * f(x0)
    A_eval = prob.materialize(prob.eval_cohort(64)).astype(np.float32)
    f0 = float(np.mean(np.abs(A_eval @ prob.x0.astype(np.float32)).sum(axis=-1)))
    target = target_frac * f0
    hist = fleet_run(
        prob, sampler, stepsize, algorithm=alg, mode="perm", k=k, p=p,
        T=T, target=target, seed=seed, measure_wire=measure_wire,
        tracker=tracker,
    )
    stats = hist["participation"]
    out = {
        "rounds_to_target": float(hist["rounds_to_target"]),
        "target": target,
        "f0": f0,
        "final_f": hist["f_x"][-1],
        "downlink_bits_analytic": hist["s2w_bits_total"],
        "downlink_bits_per_participant_round": hist["bits_per_participant_round"],
        "uplink_bits_analytic": hist["w2s_bits_total"],
        "join_bits_analytic": hist["join_bits_total"],
        "participants_mean": stats.participant_rounds / max(stats.rounds, 1),
        "unique_clients": float(stats.unique_clients),
        "mean_fill": stats.mean_fill,
        "fresh_frac": stats.fresh_frac,
        "goodput": stats.goodput,
    }
    if measure_wire:
        out["downlink_bits_measured"] = hist["wire_bits_total"]
    return out


def bench(
    tracker=None,
    out_dir: Optional[str] = None,
    cells: Optional[Sequence[Tuple[str, str, str]]] = None,
    *,
    population: int = 4096,
    cohort: int = 16,
    d: int = 64,
    T: int = 120,
    seed: int = 0,
    measure_wire: bool = True,
):
    """Run the matrix; one BENCH_scenario_<cell>.json per cell plus gated
    aggregate rows for the ``scenario`` suite artifact."""
    from repro import obs

    out_dir = out_dir or DEFAULT_OUT
    cells = list(cells if cells is not None else DEFAULT_CELLS)
    rows = []
    for alg, scheme, mix in cells:
        cid = cell_id(alg, scheme, mix)
        sink = obs.BenchJsonSink(f"scenario_{cid}", out_dir, seed=seed, gates=[])
        cell_tracker = obs.CompositeTracker(sink, tracker)
        t0 = time.time()
        m = run_cell(alg, scheme, mix, population=population, cohort=cohort,
                     d=d, T=T, seed=seed, measure_wire=measure_wire,
                     tracker=cell_tracker)
        dt_us = (time.time() - t0) * 1e6
        sink.log(m)
        sink.finish()
        rows.append((f"scenario/{cid}/rounds_to_target", dt_us, m["rounds_to_target"]))
        rows.append((f"scenario/{cid}/s2w_bits", dt_us, m["downlink_bits_analytic"]))
        rows.append((f"scenario/{cid}/goodput", dt_us, m["goodput"]))
        rows.append((f"scenario/{cid}/final_f", dt_us, m["final_f"]))
    return rows


# ---------------------------------------------------------------------------
# Folded-in stepsize axis (was benchmarks/stepsize_grid.py): paper Table 3/6
# tuned multiplicative Polyak factors, App. A protocol. Row names keep the
# historical ``stepsize_grid/...`` prefix so committed baselines stay valid.
# ---------------------------------------------------------------------------


def tune(method: str, prob, T=250, factors=None, seed=0):
    """Sweep the factor grid for one method; return (best factor, final f)."""
    from repro.core import compressors as C
    from repro.core import ef21p, marina_p, stepsizes

    d, n = prob.d, prob.n
    k = max(1, d // n)
    p, alpha = k / d, k / d
    factors = factors or [2.0**e for e in range(-7, 6, 2)]
    best = (None, float("inf"))
    for f in factors:
        if method == "ef21p":
            ss = stepsizes.EF21PPolyak(alpha=alpha, f_star=0.0, factor=f)
            h = ef21p.run(prob, C.TopK(k=k), ss, T=T, seed=seed, record_every=T - 1)
        else:
            omega = float(n - 1) if method == "perm" else d / k - 1.0
            ss = stepsizes.MarinaPPolyak(omega=omega, p=p, f_star=0.0, factor=f)
            h = marina_p.run(prob, mode=method, k=k, p=p, stepsize=ss, T=T,
                             seed=seed, record_every=T - 1)
        final = h["f_x"][-1]
        if final < best[1]:
            best = (f, final)
    return best


def polyak_factor_grid(tracker=None, *, prob=None, T=250, factors=None,
                       methods=("ef21p", "same", "ind", "perm"), seed=0):
    """The legacy stepsize_grid suite body (row names unchanged)."""
    from repro.core import problems

    rows = []
    if prob is None:
        prob = problems.generate_problem(n=10, d=120, noise_scale=1.0, seed=0)
    for method in methods:
        t0 = time.time()
        f, final = tune(method, prob, T=T, factors=factors, seed=seed)
        dt = (time.time() - t0) * 1e6
        rows.append((f"stepsize_grid/polyak/{method}/best_factor", dt, f))
        rows.append((f"stepsize_grid/polyak/{method}/final_subopt", dt, final))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--full", action="store_true",
                    help="full matrix (3 schemes x 4 mixes) instead of the 2x2x2 default")
    ap.add_argument("--population", type=int, default=4096)
    ap.add_argument("--cohort", type=int, default=16)
    ap.add_argument("-d", type=int, default=64)
    ap.add_argument("-T", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    rows = bench(out_dir=args.out, cells=FULL_CELLS if args.full else None,
                 population=args.population, cohort=args.cohort, d=args.d,
                 T=args.T, seed=args.seed)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
