"""Pallas-kernel microbenchmarks (interpret mode on CPU — correctness-path
timings; the real perf story is the dry-run roofline, §Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def bench(tracker=None):
    rows = []
    d = 1 << 16
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))
    rows.append(("kernels/block_topk/pallas_interp",
                 _time(ops.block_topk, x, k_per_block=64, block=1024), d))
    rows.append(("kernels/block_topk/jnp_ref",
                 _time(jax.jit(lambda v: ref.block_topk_ref(v, k_per_block=64, block=1024)), x), d))
    rows.append(("kernels/bernk/pallas_interp",
                 _time(ops.bernk, x, keep_prob=0.1, seed=3), d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d,))
    rot = jnp.int32(2)
    rows.append(("kernels/rotk_apply/pallas_interp",
                 _time(ops.rotk_apply, w, x, rot, n=16, worker=3), d))
    A = jax.random.normal(jax.random.PRNGKey(2), (1024, 1024))
    xx = jax.random.normal(jax.random.PRNGKey(3), (1024,))
    rows.append(("kernels/l1_subgrad/pallas_interp", _time(ops.l1_subgrad, A, xx), 1024))
    rows.append(("kernels/l1_subgrad/jnp_ref",
                 _time(jax.jit(ref.l1_subgrad_ref), A, xx), 1024))
    return rows
