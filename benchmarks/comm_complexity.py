"""Corollaries 1 & 2: measured rounds-to-epsilon vs closed-form predictions.

For a grid of compression levels, measures T_eps = rounds until
f(x^t) - f* <= eps and reports the ratio to the theory complexity
(constant factors absorbed; the *scaling* in alpha / omega is the claim).
"""
from __future__ import annotations

import time

from repro.core import comm_model, compressors as C
from repro.core import ef21p, marina_p, problems, stepsizes


def rounds_to_eps(hist, eps):
    for t, f in zip(hist["t"], hist["f_x"]):
        if f <= eps:
            return t + 1
    return None


def bench(tracker=None):
    rows = []
    prob = problems.generate_problem(n=8, d=128, noise_scale=1.0, seed=0)
    eps = 0.05 * float(prob.f(prob.x0))
    d, n = prob.d, prob.n

    for k in (4, 16, 64):
        alpha = k / d
        ss = stepsizes.EF21PPolyak(alpha=alpha, f_star=0.0)
        t0 = time.time()
        h = ef21p.run(prob, C.TopK(k=k), ss, T=4000)
        dt = (time.time() - t0) * 1e6
        T_meas = rounds_to_eps(h, eps) or -1
        T_theory = comm_model.ef21p_iteration_complexity(prob.L0, prob.R0_sq, alpha, eps)
        rows.append((f"corollary1/ef21p/k{k}/rounds", dt, T_meas))
        rows.append((f"corollary1/ef21p/k{k}/theory_ratio", dt,
                     T_meas / T_theory if T_meas > 0 else -1))

    for k in (4, 16, 64):
        p = k / d
        omega = d / k - 1.0
        ss = stepsizes.MarinaPPolyak(omega=omega, p=p, f_star=0.0)
        t0 = time.time()
        h = marina_p.run(prob, mode="ind", k=k, p=p, stepsize=ss, T=4000)
        dt = (time.time() - t0) * 1e6
        T_meas = rounds_to_eps(h, eps) or -1
        T_theory = comm_model.marina_p_iteration_complexity(
            prob.L0, prob.L0_tilde, prob.R0_sq, omega, d, float(k), eps)
        rows.append((f"corollary2/marina_ind/k{k}/rounds", dt, T_meas))
        rows.append((f"corollary2/marina_ind/k{k}/theory_ratio", dt,
                     T_meas / T_theory if T_meas > 0 else -1))
    return rows
