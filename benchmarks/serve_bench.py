"""Serve latency benchmark: prefill/decode p50/p99 + tokens/s gates.

Drives :class:`repro.serve.DecodeEngine` on the gemma-2b smoke config for a
fixed request schedule and reduces the emitted ``serve/request`` span tree
(DESIGN.md §10) to gateable scalars:

  serve/prefill_p50_ms / serve/prefill_p99_ms
  serve/decode_p50_ms  / serve/decode_p99_ms
  serve/tokens_per_s

The percentiles come from the span durations (linear interpolation —
repro.obs.hist), not wall-clock re-timing, so the benchmark exercises the
same event stream the analyzer consumes. This closes the ROADMAP follow-up
"extend gates to serve-latency p50/p99": the committed
``benchmarks/baselines/BENCH_serve.json`` carries lower-is-better latency
gates and a higher-is-better tokens/s gate for benchmarks/bench_diff.py.

Usage: PYTHONPATH=src python benchmarks/serve_bench.py
       (or via the harness: python -m benchmarks.run serve)
"""
from __future__ import annotations

import jax

from repro import configs, obs
from repro.models import lm
from repro.obs import analyze
from repro.obs.hist import percentile
from repro.serve import DecodeEngine

N_REQUESTS = 12
BATCH = 2
PROMPT_LEN = 8
NEW_TOKENS = 16


def _span_durations(events, name):
    return sorted(
        float(e["t1"]) - float(e["t0"])
        for e in analyze.span_events(events)
        if e["name"] == name
    )


def bench(tracker=None):
    cfg = configs.get_smoke("gemma-2b")
    params = lm.lm_init(cfg, jax.random.PRNGKey(0))
    mem = obs.MemoryTracker()
    # span/timer events also stream into the harness sink (span/* timers in
    # the BENCH artifact) while the local MemoryTracker feeds the reduction
    eng_tracker = obs.CompositeTracker(mem, tracker) if tracker is not None else mem
    eng = DecodeEngine(cfg, params, cache_len=PROMPT_LEN + NEW_TOKENS,
                       batch_size=BATCH, tracker=eng_tracker)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (BATCH, PROMPT_LEN), 0, cfg.vocab_size
    )
    eng.run(prompts, n_new_tokens=NEW_TOKENS)  # compile warm-up
    warm = len(mem.events)
    for i in range(N_REQUESTS):
        eng.run(prompts, n_new_tokens=NEW_TOKENS, seed=i)
    events = mem.events[warm:]

    rows = []
    for phase in ("prefill", "decode"):
        durs = _span_durations(events, phase)
        assert len(durs) == N_REQUESTS, (phase, len(durs))
        for q, tag in ((0.50, "p50"), (0.99, "p99")):
            ms = percentile(durs, q) * 1e3
            rows.append((f"serve/{phase}_{tag}_ms", ms * 1e3, round(ms, 4)))
    toks = sorted(
        float(e["attrs"]["tokens_per_s"])
        for e in analyze.span_events(events)
        if e["name"] == "serve/request"
    )
    assert len(toks) == N_REQUESTS
    decode_durs = _span_durations(events, "decode")
    rows.append(("serve/tokens_per_s", percentile(decode_durs, 0.50) * 1e6,
                 round(percentile(toks, 0.50), 2)))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in bench():
        print(f"{name},{us:.1f},{derived}")
