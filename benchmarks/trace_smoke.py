"""Seeded chaos training run emitting a JSONL span trace (CI trace-smoke).

Drives MARINA-P on the paper's L1 workload through a fault-injected fleet
(drop + straggler — the acceptance chaos model for the trace pipeline) with
a :class:`repro.obs.JsonlTracker` attached, so the log carries the full
round/subgrad/stepsize/broadcast/link span tree (DESIGN.md §10). CI then
feeds the log to ``python -m repro.obs.analyze`` to validate the spans,
export a Perfetto trace, and require at least one degraded round to be
attributed to a specific worker link.

Deterministic: the fault injectors, the algorithm, and the span ids are all
seeded, so two runs produce structurally identical span trees (timestamps
aside) — tests/test_trace.py asserts exactly that.

Usage: PYTHONPATH=src python benchmarks/trace_smoke.py --out runs/trace/run.jsonl
"""
from __future__ import annotations

import argparse
import os

from repro import obs
from repro.core import marina_p, problems, stepsizes
from repro.transport import FaultSpec

CHAOS_SPEC = FaultSpec(drop=0.15, straggler=0.2, straggler_ticks=3, seed=7)
ROUNDS = 24


def run(out: str, *, rounds: int = ROUNDS, seed: int = 1) -> str:
    prob = problems.generate_problem(n=8, d=64, noise_scale=1.0, seed=0)
    k = prob.d // prob.n
    p = k / prob.d
    ss = stepsizes.MarinaPPolyak(omega=prob.n - 1, p=p, f_star=prob.f_star)
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    tr = obs.JsonlTracker(out)
    marina_p.run(prob, mode="perm", k=k, p=p, stepsize=ss, T=rounds,
                 seed=seed, transport=CHAOS_SPEC, tracker=tr)
    tr.finish()
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="runs/trace/run.jsonl")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args(argv)
    path = run(args.out, rounds=args.rounds, seed=args.seed)
    n_spans = sum(1 for e in obs.read_jsonl(path) if e.get("kind") == "span")
    print(f"wrote {path} ({n_spans} span events over {args.rounds} rounds)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
