"""Paper Figure 1 / Figure 7: EF21-P(TopK) vs MARINA-P(same/ind/PermK),
constant vs Polyak stepsizes, under equal per-worker downlink bit budgets.

Setup follows §5/App.A: f_i = ||A_i x||_1, K = d/n, p = K/d, Algorithm 3
datagen with noise scales controlling sigma_A. Sizes are reduced by default
(CPU container); pass scale="paper" for d=1000, n in {10,100}.
"""
from __future__ import annotations

import time

import jax

from repro.core import compressors as C
from repro.core import ef21p, marina_p, problems, stepsizes


def run_suite(*, d=200, n=10, noise=1.0, budget_bits=None, T=600, seed=0,
              tuned_factor=1.0):
    prob = problems.generate_problem(n=n, d=d, noise_scale=noise, seed=seed)
    k = max(1, d // n)
    p = k / d
    alpha = k / d
    omega_rand = d / k - 1.0
    omega_perm = float(n - 1)
    results = {}

    def record(name, fn):
        t0 = time.time()
        hist = fn()
        dt = time.time() - t0
        rounds = max(hist["ledger"].rounds, 1)
        results[name] = {
            "final_subopt": hist["f_x"][-1],
            "rounds": rounds,
            "us_per_round": dt / rounds * 1e6,
            "bits_per_worker": hist["ledger"].s2w_bits,
        }

    kw = dict(T=None, bit_budget=budget_bits) if budget_bits else dict(T=T)

    # --- constant stepsizes (optimal formula x tuned factor) -----------------
    g_e = stepsizes.ef21p_optimal_constant(prob.R0_sq, prob.L0, alpha, T) * tuned_factor
    record("ef21p_topk_const", lambda: ef21p.run(
        prob, C.TopK(k=k), stepsizes.Constant(g_e), seed=seed, **kw))
    for mode, omega in (("same", omega_rand), ("ind", omega_rand), ("perm", omega_perm)):
        g_m = stepsizes.marina_p_optimal_constant(
            prob.R0_sq, prob.L0, prob.L0_tilde, omega, p, T) * tuned_factor
        record(f"marina_{mode}_const", lambda g=g_m, m=mode: marina_p.run(
            prob, mode=m, k=k, p=p, stepsize=stepsizes.Constant(g), seed=seed, **kw))

    # --- Polyak stepsizes ------------------------------------------------------
    record("ef21p_topk_polyak", lambda: ef21p.run(
        prob, C.TopK(k=k),
        stepsizes.EF21PPolyak(alpha=alpha, f_star=0.0, factor=tuned_factor),
        seed=seed, **kw))
    for mode, omega in (("same", omega_rand), ("ind", omega_rand), ("perm", omega_perm)):
        record(f"marina_{mode}_polyak", lambda m=mode, o=omega: marina_p.run(
            prob, mode=m, k=k, p=p,
            stepsize=stepsizes.MarinaPPolyak(omega=o, p=p, f_star=0.0, factor=tuned_factor),
            seed=seed, **kw))
    return results


def bench(tracker=None):
    """CSV rows for benchmarks.run (+ per-method bits/round telemetry)."""
    rows = []
    for n in (10, 50):
        res = run_suite(d=200, n=n, noise=1.0, T=400)
        for name, r in res.items():
            rows.append((f"fig1/n{n}/{name}", r["us_per_round"], r["final_subopt"]))
            if tracker is not None:
                tracker.log({f"fig1/n{n}/{name}": {
                    "bits_per_round": r["bits_per_worker"] / r["rounds"],
                    "rounds": r["rounds"]}})
    return rows
