"""Paper Table 2: data-dissimilarity sigma_A per (n, noise scale).

Reproduces the generation routine at the paper's exact sizes (d=1000,
n in {10,100}, s in {0.1, 1, 10}) and reports sigma_A; the paper's values
are 0.09/0.88/5.60 (n=10) and 0.10/0.83/5.91 (n=100).
"""
from __future__ import annotations

import time

from repro.core import problems

PAPER = {(10, 0.1): 0.09, (10, 1.0): 0.88, (10, 10.0): 5.60,
         (100, 0.1): 0.10, (100, 1.0): 0.83, (100, 10.0): 5.91}


def bench(d=1000, tracker=None):
    rows = []
    for (n, s), paper_val in PAPER.items():
        t0 = time.time()
        prob = problems.generate_problem(n=n, d=d, noise_scale=s, seed=0)
        dt = (time.time() - t0) * 1e6
        rows.append((f"table2/sigmaA/n{n}/s{s}", dt, prob.sigma_A))
        if tracker is not None:
            tracker.log({"table2": {f"n{n}/s{s}": {
                "sigma_A": prob.sigma_A, "paper": paper_val,
                "abs_err": abs(prob.sigma_A - paper_val)}}})
    return rows
